//! Paged-KV suite — artifact-free, in the CI `build` job (debug *and*
//! release) alongside `engine_parity` and `sched`.
//!
//! Two halves:
//!
//! 1. **Allocator properties** — a deterministic hand-rolled-PRNG harness
//!    (`tensor::Rng`, the repo's xorshift; there is no rand dep) drives
//!    thousands of random alloc/extend/truncate/reset sequences against
//!    [`BlockAllocator`] and the paged [`KvCache`], asserting the pool
//!    invariants after every single operation: no block owned by two
//!    rows, free + live == pool size, `reset_row` returns exactly the
//!    row's blocks, page tables never alias.
//! 2. **Differential fuzz** — random staggered-arrival workloads (from
//!    `sched::generate_load`, the same generator the serving bench uses)
//!    run through the scheduler with paged vs contiguous caches, every
//!    generated token stream held together with `assert_eq!` — the PR 3
//!    bit-identity discipline extended to the memory layout. Backpressure
//!    (a pool too small for the offered load) must delay requests, never
//!    change their tokens.

use std::collections::HashSet;

use lota_qaf::engine::{greedy_decode, greedy_decode_paged, BlockAllocator, Engine, KvCache};
use lota_qaf::model;
use lota_qaf::quant::rtn_quantize;
use lota_qaf::sched::{generate_load, LoadSpec, RequestSpec, SchedOptions, Scheduler};
use lota_qaf::tensor::Rng;

fn plain_engine(seed: u64) -> Engine {
    let cfg = lota_qaf::config::preset("tiny").unwrap();
    let mut rng = Rng::new(seed);
    let fp = model::init_fp(&cfg, &mut rng);
    let store = model::quantize_store(&cfg, &fp, |_, _, w| {
        Ok(rtn_quantize(w, cfg.group_size, 4))
    })
    .unwrap();
    Engine::from_store(&cfg, &store, 4).unwrap()
}

/// Model-checked allocator fuzz: mirror every alloc/release in a plain
/// ownership table and assert the allocator never double-grants, never
/// loses a block, and always accounts free + live == total.
#[test]
fn block_allocator_never_double_grants_or_leaks() {
    let mut rng = Rng::new(0xb10c);
    for total in [1usize, 2, 7, 32] {
        let mut a = BlockAllocator::new(total);
        // ownership model as a plain Vec so the replay is fully
        // deterministic (no hash-order dependence)
        let mut owned: Vec<usize> = Vec::new();
        for op in 0..2_000usize {
            if rng.below(2) == 0 {
                match a.alloc() {
                    Some(id) => {
                        assert!(id < total, "op {op}: granted id {id} outside pool {total}");
                        assert!(
                            !owned.contains(&id),
                            "op {op}: block {id} granted while already owned"
                        );
                        owned.push(id);
                    }
                    None => {
                        assert_eq!(
                            owned.len(),
                            total,
                            "op {op}: pool claims dry with {} of {total} owned",
                            owned.len()
                        );
                    }
                }
            } else if !owned.is_empty() {
                // release a pseudo-random owned block
                let pick = rng.below(owned.len());
                let id = owned.swap_remove(pick);
                a.release(id);
            }
            assert_eq!(a.in_use(), owned.len(), "op {op}: in_use drifted from the model");
            assert_eq!(
                a.free_blocks() + owned.len(),
                total,
                "op {op}: free + live != pool size"
            );
        }
    }
}

/// The paged-cache invariants, checked after every operation of a long
/// random alloc(grow)/truncate/reset sequence over many rows.
fn assert_cache_invariants(c: &KvCache, op: usize) {
    let bs = c.block_size().expect("paged cache");
    let total = c.total_blocks().unwrap();
    let mut live = 0usize;
    let mut seen: HashSet<usize> = HashSet::new();
    for row in 0..c.batch() {
        let table = c.row_block_ids(row);
        // a page table holds exactly the blocks its length needs
        assert_eq!(
            table.len(),
            c.pos_len(row).div_ceil(bs),
            "op {op}: row {row} holds {} blocks for {} positions (bs {bs})",
            table.len(),
            c.pos_len(row)
        );
        for &id in table {
            assert!(id < total, "op {op}: row {row} maps block {id} outside pool {total}");
            assert!(seen.insert(id), "op {op}: block {id} owned by two rows");
        }
        live += table.len();
    }
    assert_eq!(
        c.free_blocks().unwrap() + live,
        total,
        "op {op}: free + live != pool size"
    );
}

#[test]
fn paged_cache_invariants_hold_under_random_ops() {
    let mut rng = Rng::new(0x9a9e);
    // (rows, capacity, block_size, pool) shapes incl. a pool too small to
    // hold every row at capacity — exhaustion is part of the domain
    for (batch, cap, bs, pool) in
        [(4usize, 32usize, 4usize, 16usize), (3, 48, 7, 9), (8, 16, 1, 40), (2, 64, 16, 4)]
    {
        let mut c = KvCache::new_paged(1, batch, cap, 8, bs, pool).unwrap();
        for op in 0..3_000usize {
            let row = rng.below(batch);
            match rng.below(4) {
                // extend by 1..=9 positions — may legitimately fail on
                // capacity or a dry pool; the cache must stay consistent
                // either way (failed grows roll back completely)
                0 | 1 => {
                    let n = 1 + rng.below(9);
                    let before = (c.pos_len(row), c.row_block_ids(row).len());
                    if c.grow_row(row, n).is_err() {
                        assert_eq!(
                            (c.pos_len(row), c.row_block_ids(row).len()),
                            before,
                            "op {op}: failed grow mutated row {row}"
                        );
                    }
                }
                // truncate to a random fraction of the live length
                2 => {
                    let new_len = if c.pos_len(row) == 0 {
                        0
                    } else {
                        rng.below(c.pos_len(row) + 1)
                    };
                    c.truncate_row(row, new_len);
                    assert_eq!(c.pos_len(row), new_len);
                }
                // reset: the row's blocks — exactly them — come back
                _ => {
                    let held = c.row_block_ids(row).len();
                    let free_before = c.free_blocks().unwrap();
                    c.reset_row(row);
                    assert_eq!(
                        c.free_blocks().unwrap(),
                        free_before + held,
                        "op {op}: reset_row returned a different count than row {row} held"
                    );
                    assert_eq!(c.pos_len(row), 0);
                    assert!(c.row_block_ids(row).is_empty());
                }
            }
            assert_cache_invariants(&c, op);
        }
    }
}

/// Run a workload through a scheduler, dripping submissions between steps
/// on a deterministic schedule (`chunks[i]` arrivals before step i) so
/// admission waves, slot reuse, and backpressure all get exercised
/// without any wall-clock dependence. Returns (text, tokens) in
/// submission order.
fn run_staggered(
    engine: &Engine,
    load: &[lota_qaf::sched::LoadRequest],
    opts: &SchedOptions,
    chunks: &[usize],
) -> Vec<(String, usize)> {
    let mut s = Scheduler::new(engine, opts).unwrap();
    let mut next = 0usize;
    let mut ids = Vec::with_capacity(load.len());
    let mut ci = 0usize;
    loop {
        let take = if ci < chunks.len() { chunks[ci] } else { 1 };
        ci += 1;
        for _ in 0..take {
            if next < load.len() {
                ids.push(
                    s.submit(RequestSpec::new(load[next].prompt.as_str(), load[next].max_new))
                        .unwrap(),
                );
                next += 1;
            }
        }
        if next >= load.len() && s.is_idle() {
            break;
        }
        s.step().unwrap();
    }
    let responses = s.take_finished();
    assert_eq!(responses.len(), load.len());
    ids.iter()
        .map(|id| {
            let r = responses.iter().find(|r| r.id == *id).unwrap();
            (r.text.clone(), r.tokens)
        })
        .collect()
}

/// Differential fuzz: the same staggered workload served paged vs
/// contiguous emits identical token streams, request by request — and
/// both match the one-shot single-prompt decode, so neither layout's
/// batching leaks into anyone's tokens.
#[test]
fn paged_and_contiguous_schedulers_emit_identical_streams() {
    let engine = plain_engine(640);
    let mut rng = Rng::new(0xd1ff);
    for seed in [11u64, 29, 47] {
        let spec = LoadSpec {
            n_requests: 14,
            rate_per_sec: 50.0,
            seed,
            task: "arith".into(),
            max_new_mix: vec![2, 5, 11],
        };
        let load = generate_load(&spec).unwrap();
        // one deterministic drip schedule shared by both arms
        let chunks: Vec<usize> = (0..load.len()).map(|_| rng.below(3)).collect();
        let paged = run_staggered(
            &engine,
            &load,
            &SchedOptions { max_batch: 3, ..SchedOptions::default() },
            &chunks,
        );
        let contiguous = run_staggered(
            &engine,
            &load,
            &SchedOptions { max_batch: 3, kv_paged: false, ..SchedOptions::default() },
            &chunks,
        );
        for (i, (p, c)) in paged.iter().zip(&contiguous).enumerate() {
            assert_eq!(p, c, "seed {seed}: request {i} diverged between layouts");
        }
        // and against ground truth: the one-shot decode of each prompt
        for (i, req) in load.iter().enumerate() {
            let want = greedy_decode(&engine, &[req.prompt.clone()], req.max_new).unwrap();
            assert_eq!(
                paged[i],
                (want[0].text.clone(), want[0].tokens),
                "seed {seed}: request {i} diverged from one-shot decode"
            );
        }
    }
}

/// Backpressure fuzz: a pool far too small for the offered load forces
/// admission denials on most steps — requests must come out delayed but
/// token-identical to an unconstrained contiguous run, and the denial
/// counter must actually fire.
#[test]
fn backpressure_delays_but_never_changes_tokens() {
    let engine = plain_engine(641);
    let spec = LoadSpec {
        n_requests: 12,
        rate_per_sec: 50.0,
        seed: 83,
        task: "arith".into(),
        max_new_mix: vec![3, 8, 16],
    };
    let load = generate_load(&spec).unwrap();
    let chunks: Vec<usize> = vec![4; load.len()]; // arrive much faster than service
    // 3 blocks × 16 tokens: roughly one long or two short requests in
    // flight at a time, against 6 nominal slots
    let tight = SchedOptions {
        max_batch: 6,
        kv_budget_bytes: 3 * engine.kv_block_bytes(16),
        kv_paged: true,
        kv_block_size: 16,
        ..SchedOptions::default()
    };
    let mut s = Scheduler::new(&engine, &tight).unwrap();
    let mut next = 0usize;
    let mut ids = Vec::new();
    let mut ci = 0usize;
    loop {
        let take = if ci < chunks.len() { chunks[ci] } else { 0 };
        ci += 1;
        for _ in 0..take {
            if next < load.len() {
                ids.push(
                    s.submit(RequestSpec::new(load[next].prompt.as_str(), load[next].max_new))
                        .unwrap(),
                );
                next += 1;
            }
        }
        if next >= load.len() && s.is_idle() {
            break;
        }
        s.step().unwrap();
    }
    let responses = s.take_finished();
    assert_eq!(responses.len(), load.len(), "backpressure dropped requests");
    let stats = s.sched_stats();
    assert!(
        stats.admission_denied > 0,
        "a 3-block pool under a 12-request burst never denied admission"
    );
    assert!(stats.peak_active <= 3, "pool of 3 blocks held {} rows", stats.peak_active);
    assert!(!stats.block_util.is_empty());
    for (i, id) in ids.iter().enumerate() {
        let got = responses.iter().find(|r| r.id == *id).unwrap();
        let want = greedy_decode(&engine, &[load[i].prompt.clone()], load[i].max_new).unwrap();
        assert_eq!(got.text, want[0].text, "request {i}: backpressure changed the tokens");
        assert_eq!(got.tokens, want[0].tokens, "request {i}");
    }
    // nothing leaked once drained
    let (free, total) = s.block_pool().unwrap();
    assert_eq!(free, total);
}

/// One-shot sanity for the paged decode entry point on the plain engine
/// (the merged-checkpoint version is pinned in `tests/engine_parity.rs`):
/// identical generations and identical work accounting vs the contiguous
/// default, across block sizes.
#[test]
fn one_shot_paged_decode_round_trip() {
    let engine = plain_engine(642);
    let prompts: Vec<String> = (0..6).map(|i| format!("{i} * 2 =")).collect();
    let want = greedy_decode(&engine, &prompts, 7).unwrap();
    for bs in [1usize, 4, 16, 128] {
        let (got, _) = greedy_decode_paged(&engine, &prompts, 7, bs).unwrap();
        for (g, w) in got.iter().zip(&want) {
            assert_eq!(g.text, w.text, "bs={bs}");
            assert_eq!(g.tokens, w.tokens, "bs={bs}");
        }
    }
}
