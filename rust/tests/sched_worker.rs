//! Async front-end integration suite: the scheduler worker thread
//! (`sched::SchedWorker`) and the HTTP/SSE transport (`serve::listen`).
//!
//! The two contracts under test:
//!
//! 1. **Parity** — moving the scheduler onto a worker thread behind an
//!    MPSC channel changes *when* work is admitted, never *what* is
//!    decoded: per request, worker output is bit-identical to the
//!    synchronous `step()` loop and to the one-shot
//!    `engine::greedy_decode` (extending the `tests/engine_parity.rs` /
//!    `tests/sched.rs` contracts across the thread boundary).
//! 2. **Lifecycle edges** — double-cancel, cancel-after-finish,
//!    submit-after-shutdown, zero-`max_new` streams, and byte-for-byte
//!    agreement between what the SSE transport carries and what the
//!    in-process stream events render to.

use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::thread;
use std::time::Duration;

use lota_qaf::config::{Backend, SchedConfig};
use lota_qaf::data::tokenizer;
use lota_qaf::engine::{greedy_decode, Engine};
use lota_qaf::sched::{
    generate_load, FinishReason, LoadSpec, RequestSpec, SchedOptions, SchedWorker, Scheduler,
    StreamEvent, SubmitError, WorkerConfig,
};
use lota_qaf::serve::listen::{finish_event_json, start_event_json, token_event_json};
use lota_qaf::serve::{ListenServer, ServeOptions, ServePath};

mod common;
use common::merged_tiny;

fn opts(max_batch: usize) -> SchedOptions {
    SchedOptions { max_batch, ..SchedOptions::default() }
}

/// RTN-only tiny engine — cheap enough for seed scans (no merge loop).
fn plain_engine(seed: u64) -> Engine {
    let cfg = lota_qaf::config::preset("tiny").unwrap();
    let mut rng = lota_qaf::tensor::Rng::new(seed);
    let fp = lota_qaf::model::init_fp(&cfg, &mut rng);
    let store = lota_qaf::model::quantize_store(&cfg, &fp, |_, _, w| {
        Ok(lota_qaf::quant::rtn_quantize(w, cfg.group_size, 4))
    })
    .unwrap();
    Engine::from_store(&cfg, &store, 4).unwrap()
}

fn spawn_worker(engine: Engine, max_batch: usize) -> SchedWorker {
    SchedWorker::spawn(engine, opts(max_batch), WorkerConfig::default()).unwrap()
}

/// The tentpole pin: requests submitted through the worker's command
/// channel decode bit-identically to the same requests driven through a
/// synchronous `step()` loop, and both match the one-shot decode. Batch
/// composition differs across the three (the worker interleaves
/// admission with channel drains), so equality here is exactly the
/// "scheduling never leaks into tokens" invariant.
#[test]
fn worker_output_is_bit_identical_to_the_synchronous_loop() {
    let (cfg, store) = merged_tiny(401);
    let prompts: Vec<String> = (0..9).map(|i| format!("{i} + {} =", (i * 3) % 10)).collect();
    let max_new = 8;

    // worker-threaded run
    let worker = spawn_worker(Engine::from_store(&cfg, &store, 4).unwrap(), 3);
    let client = worker.client();
    let mut worker_ids = Vec::new();
    for p in &prompts {
        worker_ids.push(client.submit(RequestSpec::new(p.as_str(), max_new)).unwrap());
    }
    let report = worker.shutdown().unwrap();
    assert_eq!(report.responses.len(), prompts.len());

    // synchronous reference run on identical weights
    let engine = Engine::from_store(&cfg, &store, 4).unwrap();
    let mut sched = Scheduler::new(&engine, &opts(3)).unwrap();
    let mut sync_ids = Vec::new();
    for p in &prompts {
        sync_ids.push(sched.submit(RequestSpec::new(p.as_str(), max_new)).unwrap());
    }
    sched.run_until_idle().unwrap();
    let sync_responses = sched.take_finished();

    let one_shot = greedy_decode(&engine, &prompts, max_new).unwrap();
    for (i, (wid, sid)) in worker_ids.iter().zip(&sync_ids).enumerate() {
        let w = report.responses.iter().find(|r| r.id == *wid).unwrap();
        let s = sync_responses.iter().find(|r| r.id == *sid).unwrap();
        assert_eq!(w.text, s.text, "prompt {i}: worker diverged from the synchronous loop");
        assert_eq!(w.tokens, s.tokens, "prompt {i}: token count diverged");
        assert_eq!(w.reason, s.reason, "prompt {i}: finish reason diverged");
        assert_eq!(w.text, one_shot[i].text, "prompt {i}: worker diverged from one-shot");
        assert_eq!(w.tokens, one_shot[i].tokens);
    }
    // every submit crossed the channel exactly once, with a measured,
    // finite handoff
    assert_eq!(report.stats.handoff_ms.len(), prompts.len());
    assert!(report.stats.handoff_ms.min() >= 0.0);
    assert!(report.stats.handoff_ms.stats().max.is_finite());
}

/// Cancel twice: the first may land (scan seeds for one where the victim
/// is still decoding — EOS is weight luck on a random tiny model), the
/// second must report false, and so must a cancel after a natural finish.
#[test]
fn double_cancel_and_cancel_after_finish_report_false() {
    for seed in 0..32u64 {
        let worker = spawn_worker(plain_engine(600 + seed), 2);
        let client = worker.client();
        let (victim, events) = client.submit_streaming(RequestSpec::new("1 + 2 =", 64)).unwrap();
        let first = client.cancel(victim).unwrap();
        // drain the stream to the finish event — after it, the request is
        // definitively out of the scheduler
        let mut reason = None;
        for ev in events {
            if let StreamEvent::Finish(resp) = ev {
                reason = Some(resp.reason);
                break;
            }
        }
        let reason = reason.expect("stream ended without a finish event");
        let second = client.cancel(victim).unwrap();
        assert!(!second, "seed {seed}: second cancel of request {victim} reported true");

        // cancel after a natural (max_new-bounded) finish
        let (short, events) = client.submit_streaming(RequestSpec::new("3 + 4 =", 1)).unwrap();
        let finished = events.into_iter().any(|ev| matches!(ev, StreamEvent::Finish(_)));
        assert!(finished, "seed {seed}: short request never finished");
        assert!(
            !client.cancel(short).unwrap(),
            "seed {seed}: cancel after finish reported true"
        );
        worker.shutdown().unwrap();

        if first && reason == FinishReason::Cancelled {
            return; // the interesting path ran: first cancel landed mid-flight
        }
    }
    panic!("no seed kept the victim in flight long enough to observe a landed cancel");
}

/// After a shutdown request, new submits are rejected (either explicitly
/// while draining or because the worker is already gone), while the
/// in-flight request still drains to a normal finish.
#[test]
fn submit_after_shutdown_is_rejected_and_in_flight_work_drains() {
    let worker = spawn_worker(plain_engine(207), 2);
    let client = worker.client();
    let id = client.submit(RequestSpec::new("5 + 6 =", 12)).unwrap();
    client.request_shutdown();
    let err = client.submit(RequestSpec::new("7 + 8 =", 4)).unwrap_err().to_string();
    assert!(
        err.contains("shutting down") || err.contains("gone"),
        "unexpected rejection message: {err}"
    );
    let report = worker.shutdown().unwrap();
    assert_eq!(report.responses.len(), 1, "the in-flight request did not drain");
    let r = &report.responses[0];
    assert_eq!(r.id, id);
    assert_ne!(r.reason, FinishReason::Cancelled, "drain cancelled in-flight work");
    assert!(r.tokens >= 1);
}

/// A zero-`max_new` submit finishes inside the submit call itself; the
/// stream must still deliver its finish event (the router registers the
/// stream before the submit runs).
#[test]
fn zero_max_new_streams_deliver_their_finish_event() {
    let worker = spawn_worker(plain_engine(19), 2);
    let (id, events) = worker.client().submit_streaming(RequestSpec::new("1 + 1 =", 0)).unwrap();
    let events: Vec<StreamEvent> = events.into_iter().collect();
    assert_eq!(events.len(), 1, "a zero-budget request streamed tokens");
    match &events[0] {
        StreamEvent::Finish(resp) => {
            assert_eq!(resp.id, id);
            assert_eq!(resp.tokens, 0);
        }
        other => panic!("expected a finish event, got {other:?}"),
    }
    worker.shutdown().unwrap();
}

// --------------------------------------------------------------------------
// transport: the wire against the in-process streams

fn http_request(addr: SocketAddr, method: &str, path: &str, body: &str) -> String {
    let mut stream = TcpStream::connect(addr).unwrap();
    write!(
        stream,
        "{method} {path} HTTP/1.1\r\nHost: test\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    )
    .unwrap();
    let mut out = String::new();
    stream.read_to_string(&mut out).unwrap();
    out
}

/// `data:` payloads of an SSE response, in order.
fn sse_payloads(response: &str) -> Vec<String> {
    response
        .lines()
        .filter_map(|l| l.strip_prefix("data: "))
        .map(str::to_string)
        .collect()
}

fn generate_body(prompt: &str, max_new: usize) -> String {
    let mut w = lota_qaf::config::JsonWriter::new();
    w.begin_obj();
    w.key("prompt").str(prompt);
    w.key("max_new").num(max_new as f64);
    w.end_obj();
    w.finish()
}

fn serve_options() -> ServeOptions {
    ServeOptions::new(ServePath::Merged, 16)
        .backend(Backend::Native)
        .bits(4)
        .scheduled(SchedConfig::default())
}

/// Basic routes: liveness, unknown paths, cancel of an unknown id, and a
/// malformed generate body.
#[test]
fn transport_routes_health_errors_and_unknown_cancel() {
    let (cfg, store) = merged_tiny(23);
    let server = ListenServer::start(&cfg, &store, &serve_options(), "127.0.0.1:0").unwrap();
    let addr = server.local_addr();

    let health = http_request(addr, "GET", "/healthz", "");
    assert!(health.starts_with("HTTP/1.1 200 OK"), "healthz: {health}");
    assert!(health.ends_with("ok\n"), "healthz body: {health}");

    let missing = http_request(addr, "GET", "/nope", "");
    assert!(missing.starts_with("HTTP/1.1 404"), "unknown route: {missing}");

    let bad = http_request(addr, "POST", "/generate", "{\"max_new\": 4}");
    assert!(bad.starts_with("HTTP/1.1 400"), "missing prompt: {bad}");
    assert!(bad.contains("prompt"), "error should name the missing key: {bad}");

    let cancel = http_request(addr, "POST", "/cancel", "{\"id\": 999}");
    assert!(cancel.starts_with("HTTP/1.1 200"), "cancel: {cancel}");
    assert!(cancel.contains("\"cancelled\":false"), "unknown id must not cancel: {cancel}");

    server.shutdown().unwrap();
}

/// The wire test the satellite asks for: a seed-scanned staggered
/// workload driven over concurrent HTTP connections, with every
/// request's SSE stream asserted **byte-for-byte** against the
/// in-process rendering — start/token frames rebuilt from a reference
/// worker run on identical weights (decode is bit-identical, pinned
/// above), the finish frame rebuilt from this very run's
/// [`lota_qaf::sched::SchedResponse`] via the same `*_event_json`
/// helpers the server uses.
#[test]
fn transport_streams_match_in_process_streams_byte_for_byte() {
    for seed in 0..3u64 {
        let (cfg, store) = merged_tiny(300 + seed);
        let spec = LoadSpec {
            n_requests: 5,
            rate_per_sec: 50.0,
            seed: 40 + seed,
            task: "arith".into(),
            max_new_mix: vec![2, 5, 9],
        };
        let load = generate_load(&spec).unwrap();

        // reference run: capture each (prompt, max_new)'s exact token
        // stream in-process
        let reference = spawn_worker(Engine::from_store(&cfg, &store, 4).unwrap(), 3);
        let ref_client = reference.client();
        let mut ref_tokens: HashMap<(String, usize), Vec<u32>> = HashMap::new();
        for req in &load {
            let key = (req.prompt.clone(), req.max_new);
            if ref_tokens.contains_key(&key) {
                continue; // identical submissions decode identically
            }
            let (_, events) = ref_client
                .submit_streaming(RequestSpec::new(req.prompt.as_str(), req.max_new))
                .unwrap();
            let mut tokens = Vec::new();
            for ev in events {
                match ev {
                    StreamEvent::Token { token, .. } => tokens.push(token),
                    StreamEvent::Finish(_) => break,
                }
            }
            ref_tokens.insert(key, tokens);
        }
        reference.shutdown().unwrap();

        // transport run: same weights, staggered concurrent connections
        let server = ListenServer::start(&cfg, &store, &serve_options(), "127.0.0.1:0").unwrap();
        let addr = server.local_addr();
        let mut clients = Vec::new();
        for (i, req) in load.iter().enumerate() {
            let body = generate_body(&req.prompt, req.max_new);
            let key = (req.prompt.clone(), req.max_new);
            clients.push(thread::spawn(move || {
                thread::sleep(Duration::from_millis(10 * i as u64));
                (key, sse_payloads(&http_request(addr, "POST", "/generate", &body)))
            }));
        }
        let streams: Vec<((String, usize), Vec<String>)> =
            clients.into_iter().map(|h| h.join().unwrap()).collect();
        let report = server.shutdown().unwrap();
        assert_eq!(report.responses.len(), load.len(), "seed {seed}: requests went missing");

        for (key, frames) in streams {
            assert!(frames.len() >= 2, "seed {seed}: stream too short: {frames:?}");
            // the start frame carries the id; rebuild it and look up this
            // run's response for the finish frame
            let id_field = frames[0]
                .split("\"id\":")
                .nth(1)
                .and_then(|s| s.trim_end_matches('}').parse::<u64>().ok())
                .unwrap_or_else(|| panic!("seed {seed}: unparseable start frame {:?}", frames[0]));
            assert_eq!(frames[0], start_event_json(id_field), "seed {seed}: start frame");
            let tokens = &ref_tokens[&key];
            let mut expected = vec![start_event_json(id_field)];
            expected.extend(tokens.iter().map(|&t| token_event_json(id_field, t)));
            let resp = report.responses.iter().find(|r| r.id == id_field).unwrap();
            expected.push(finish_event_json(resp));
            assert_eq!(
                frames, expected,
                "seed {seed}: transport bytes diverged from the in-process stream"
            );
            // the finish frame's text is consistent with the streamed
            // tokens (dropping specials the text decoder filters)
            assert_eq!(resp.tokens, tokens.len(), "seed {seed}: token count mismatch");
            assert_eq!(resp.text, tokenizer::decode(tokens), "seed {seed}: text mismatch");
        }
    }
}

// --------------------------------------------------------------------------
// overload control: bounded submit queue, shedding, and the two 503s

/// With `submit_queue_cap` set, submits arriving while the wait queue is
/// at cap come back as a typed [`SubmitError::QueueFull`] carrying the
/// cap and a sane retry hint — and every accepted request still drains to
/// a response, with `SchedStats::queue_rejected` reconciling exactly
/// against the refusals the client saw. Whether a given submit races
/// ahead of the worker's drain is timing, so scan seeds until a run
/// actually fills the queue (the overwhelming majority do).
#[test]
fn bounded_queue_rejects_with_a_typed_queue_full_error() {
    for seed in 0..8u64 {
        let engine = plain_engine(700 + seed);
        let opts = SchedOptions { max_batch: 1, submit_queue_cap: 1, ..SchedOptions::default() };
        let worker = SchedWorker::spawn(engine, opts, WorkerConfig::default()).unwrap();
        let client = worker.client();
        let mut accepted = 0usize;
        let mut rejected = 0usize;
        // a long blocker holds the single slot while the burst lands
        client.submit(RequestSpec::new("1 + 2 =", 64)).unwrap();
        accepted += 1;
        for i in 0..12 {
            match client.submit(RequestSpec::new(format!("{i} + 1 ="), 2)) {
                Ok(_) => accepted += 1,
                Err(e) => {
                    match e.downcast_ref::<SubmitError>() {
                        Some(SubmitError::QueueFull { cap, retry_after_secs }) => {
                            assert_eq!(*cap, 1, "refusal reported the wrong cap");
                            assert!(
                                (1..=30).contains(retry_after_secs),
                                "retry hint out of range: {retry_after_secs}"
                            );
                        }
                        other => panic!("expected a typed QueueFull, got {other:?}: {e:#}"),
                    }
                    rejected += 1;
                }
            }
        }
        let report = worker.shutdown().unwrap();
        assert_eq!(report.responses.len(), accepted, "an accepted request went missing");
        assert_eq!(
            report.stats.queue_rejected, rejected,
            "client-visible refusals and SchedStats diverged"
        );
        if rejected > 0 {
            return;
        }
    }
    panic!("no seed ever drove the bounded queue to rejection");
}

/// A request whose TTFT deadline is already blown at submit
/// (`deadline_ms: 0`) streams over the wire as a normal SSE response —
/// start frame, then a finish frame with reason `"shed"` and zero tokens,
/// byte-identical to the in-process rendering — and never touches the
/// engine.
#[test]
fn wire_blown_deadline_sheds_with_a_finish_frame() {
    let (cfg, store) = merged_tiny(29);
    let server = ListenServer::start(&cfg, &store, &serve_options(), "127.0.0.1:0").unwrap();
    let addr = server.local_addr();
    let body = r#"{"prompt": "1 + 2 =", "max_new": 8, "deadline_ms": 0}"#;
    let resp = http_request(addr, "POST", "/generate", body);
    assert!(resp.starts_with("HTTP/1.1 200 OK"), "shed is a finish frame, not an error: {resp}");
    let frames = sse_payloads(&resp);
    assert_eq!(frames.len(), 2, "a shed request must stream zero tokens: {frames:?}");
    let report = server.shutdown().unwrap();
    assert_eq!(report.responses.len(), 1);
    let shed = &report.responses[0];
    assert_eq!(shed.reason, FinishReason::Shed);
    assert_eq!(shed.tokens, 0);
    assert_eq!(frames[1], finish_event_json(shed), "wire finish frame diverged");
    assert!(frames[1].contains("\"reason\":\"shed\""), "finish frame: {}", frames[1]);
    assert_eq!(report.stats.shed_at_submit, 1, "shed was not counted where it happened");
    assert_eq!(report.decode.forwards, 0, "a shed-at-submit request reached the engine");
}

/// Queue-full over the wire: with a tiny bounded queue and a burst of
/// concurrent connections, the overflow gets `503` with a `Retry-After`
/// header and the `"retriable": true` body, survivors stream normally,
/// and the 503 count reconciles with `SchedStats::queue_rejected`.
#[test]
fn wire_queue_full_is_503_with_retry_after() {
    let (cfg, store) = merged_tiny(31);
    let options = ServeOptions::new(ServePath::Merged, 16)
        .backend(Backend::Native)
        .bits(4)
        .scheduled(SchedConfig { max_batch: 1, submit_queue_cap: 1, ..SchedConfig::default() });
    for attempt in 0..4 {
        let server = ListenServer::start(&cfg, &store, &options, "127.0.0.1:0").unwrap();
        let addr = server.local_addr();
        let mut burst = Vec::new();
        for i in 0..10 {
            burst.push(thread::spawn(move || {
                http_request(addr, "POST", "/generate", &generate_body(&format!("{i} + 2 ="), 24))
            }));
        }
        let responses: Vec<String> = burst.into_iter().map(|h| h.join().unwrap()).collect();
        let report = server.shutdown().unwrap();
        let rejected: Vec<&String> =
            responses.iter().filter(|r| r.starts_with("HTTP/1.1 503")).collect();
        let ok = responses.iter().filter(|r| r.starts_with("HTTP/1.1 200")).count();
        assert_eq!(ok + rejected.len(), 10, "a request got neither a stream nor a 503");
        assert_eq!(report.responses.len(), ok, "a surviving request went missing");
        assert_eq!(
            report.stats.queue_rejected,
            rejected.len(),
            "wire 503s and SchedStats diverged"
        );
        for r in &rejected {
            let retry: u64 = r
                .lines()
                .find_map(|l| l.strip_prefix("Retry-After: "))
                .unwrap_or_else(|| panic!("queue-full 503 without Retry-After: {r}"))
                .trim()
                .parse()
                .expect("Retry-After must be whole seconds");
            assert!((1..=30).contains(&retry), "retry hint out of range: {retry}");
            assert!(r.contains("\"retriable\":true"), "queue-full body: {r}");
            assert!(r.contains("submit queue is full"), "queue-full body: {r}");
        }
        if !rejected.is_empty() {
            return;
        }
        // the worker outran all ten connects — timing luck, go again
        let _ = attempt;
    }
    panic!("no attempt ever drove the bounded queue to a wire 503");
}

/// Draining over the wire: a submit landing while the worker drains gets
/// the *other* 503 — `"retriable": false`, no `Retry-After` — because
/// backing off and retrying a server that is going away helps nobody.
/// Timing-sensitive (the in-flight blocker must still be draining when
/// the probe lands), so scan seeds.
#[test]
fn wire_draining_503_is_not_retriable() {
    for seed in 0..8u64 {
        let (cfg, store) = merged_tiny(800 + seed);
        let server = ListenServer::start(&cfg, &store, &serve_options(), "127.0.0.1:0").unwrap();
        let addr = server.local_addr();
        let client = server.client();
        // hold the worker in its drain with a long in-flight request
        client.submit(RequestSpec::new("1 + 2 =", 200)).unwrap();
        client.request_shutdown();
        let resp = http_request(addr, "POST", "/generate", &generate_body("3 + 4 =", 4));
        assert!(resp.starts_with("HTTP/1.1 503"), "draining submit got: {resp}");
        if !resp.contains("\"retriable\":false") {
            continue; // blocker finished first; the worker was gone, not draining
        }
        assert!(!resp.contains("Retry-After"), "draining must not advertise a retry: {resp}");
        let report = server.shutdown().unwrap();
        assert_eq!(report.responses.len(), 1, "the in-flight blocker did not drain");
        return;
    }
    panic!("no seed kept the worker draining long enough to observe the 503");
}
