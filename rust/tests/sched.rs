//! Scheduler integration suite — artifact-free, runs in CI as the
//! continuous-batching smoke gate alongside `engine_parity`.
//!
//! Covers the lifecycle edges the unit tests can't see in isolation:
//! cancellation mid-decode with immediate slot reclaim, zero-admission
//! steps when every slot is held, a request finishing on the very step
//! it was admitted, FIFO fairness under a persistently full batch,
//! streaming sinks, and staggered-arrival parity against the one-shot
//! decode (same kernels, so same bits).

use std::cell::RefCell;
use std::rc::Rc;

use lota_qaf::engine::{greedy_decode, Engine};
use lota_qaf::model;
use lota_qaf::quant::rtn_quantize;
use lota_qaf::sched::{
    generate_load, FinishReason, LoadSpec, RequestSpec, RequestState, SchedOptions, SchedResponse,
    Scheduler, TokenSink,
};
use lota_qaf::tensor::Rng;

mod common;
use common::merged_tiny;

fn plain_engine(seed: u64) -> Engine {
    let cfg = lota_qaf::config::preset("tiny").unwrap();
    let mut rng = Rng::new(seed);
    let fp = model::init_fp(&cfg, &mut rng);
    let store = model::quantize_store(&cfg, &fp, |_, _, w| {
        Ok(rtn_quantize(w, cfg.group_size, 4))
    })
    .unwrap();
    Engine::from_store(&cfg, &store, 4).unwrap()
}

fn opts(max_batch: usize) -> SchedOptions {
    // generous budget, default (paged) layout — the lifecycle edges run
    // on what serving actually ships
    SchedOptions { max_batch, ..SchedOptions::default() }
}

/// Cancelling an in-flight request releases its slot immediately: the
/// next step admits the waiting request while the other in-flight row
/// keeps decoding undisturbed. Whether a random tiny model EOSes early
/// is weight luck, so scan seeds for one where the victim is still
/// mid-decode after a step (the overwhelming majority are).
#[test]
fn cancellation_mid_decode_frees_the_slot() {
    for seed in 0..32u64 {
        let engine = plain_engine(500 + seed);
        let mut s = Scheduler::new(&engine, &opts(2)).unwrap();
        let a = s.submit(RequestSpec::new("1 + 2 =", 12)).unwrap();
        let b = s.submit(RequestSpec::new("3 + 4 =", 12)).unwrap();
        let c = s.submit(RequestSpec::new("5 + 6 =", 12)).unwrap();
        assert_eq!(s.state_of(c), Some(RequestState::Queued));
        s.step().unwrap(); // admit + prefill a and b; c waits
        if s.state_of(a) != Some(RequestState::Decoding)
            || s.state_of(b) != Some(RequestState::Decoding)
        {
            continue; // a victim or witness finished instantly — next seed
        }
        assert!(s.cancel(a), "cancel of an in-flight request was refused");
        assert_eq!(s.state_of(a), Some(RequestState::Cancelled));
        assert_eq!(s.active_count(), 1, "cancelled slot was not released");
        // the freed slot goes to c on the very next step, mid-generation
        let report = s.step().unwrap();
        assert_eq!(report.admitted, vec![c], "waiting request did not inherit the slot");
        s.run_until_idle().unwrap();
        let responses = s.take_finished();
        assert_eq!(responses.len(), 3);
        let cancelled = responses.iter().find(|r| r.id == a).unwrap();
        assert_eq!(cancelled.reason, FinishReason::Cancelled);
        assert!(cancelled.tokens >= 1, "victim was not actually mid-decode");
        for id in [b, c] {
            let r = responses.iter().find(|r| r.id == id).unwrap();
            assert_ne!(r.reason, FinishReason::Cancelled, "request {id} got cancelled");
        }
        return;
    }
    panic!("no seed kept a request in flight past its first step");
}

/// With every slot held, a step admits zero new requests; the queue
/// drains strictly as slots free up. This is the KV-budget edge: the
/// budget here fits exactly one full-context row, so the batch *is* one
/// slot.
#[test]
fn full_batch_admits_zero_until_a_slot_frees() {
    let engine = plain_engine(7);
    let budget = engine.cache_row_bytes(); // exactly one row fits
    // the contiguous reference layout: the budget caps the slot count
    let one_row = SchedOptions {
        max_batch: 4,
        kv_budget_bytes: budget,
        kv_paged: false,
        ..SchedOptions::default()
    };
    let mut s = Scheduler::new(&engine, &one_row).unwrap();
    assert_eq!(s.n_slots(), 1);
    let first = s.submit(RequestSpec::new("1 + 1 =", 3)).unwrap();
    let second = s.submit(RequestSpec::new("2 + 2 =", 3)).unwrap();
    let report = s.step().unwrap();
    assert_eq!(report.admitted, vec![first]);
    assert_eq!(report.queue_depth, 1);
    // as long as the first request holds the slot, admissions are empty
    let mut admitted_second_at = None;
    for step in 1..32 {
        let report = s.step().unwrap();
        if !report.admitted.is_empty() {
            assert_eq!(report.admitted, vec![second]);
            admitted_second_at = Some(step);
            break;
        }
        assert_eq!(s.state_of(second), Some(RequestState::Queued));
    }
    let admitted_at = admitted_second_at.expect("second request was never admitted");
    assert!(admitted_at >= 1);
    s.run_until_idle().unwrap();
    assert_eq!(s.take_finished().len(), 2);
}

/// A request that exhausts its token budget at prefill finishes on the
/// same step it was admitted — and its slot still turns over to the next
/// waiting request on the following step.
#[test]
fn finish_on_admission_step_hands_the_slot_over() {
    let engine = plain_engine(9);
    let mut s = Scheduler::new(&engine, &opts(1)).unwrap();
    let a = s.submit(RequestSpec::new("1 + 3 =", 1)).unwrap();
    let b = s.submit(RequestSpec::new("2 + 5 =", 1)).unwrap();
    let report = s.step().unwrap();
    assert_eq!(report.admitted, vec![a]);
    assert_eq!(report.finished, vec![a], "one-token request outlived its admission step");
    assert_eq!(report.decoded_rows, 0, "a just-admitted request must not decode-step");
    let report = s.step().unwrap();
    assert_eq!(report.admitted, vec![b]);
    assert_eq!(report.finished, vec![b]);
    assert!(s.is_idle());
    let responses = s.take_finished();
    assert_eq!(responses.len(), 2);
    for r in &responses {
        assert!(r.tokens <= 1);
    }
}

/// Step wall-time accounting ([`StepReport`]'s `*_ms` fields): every
/// phase duration is non-negative, skipped phases report exactly 0.0,
/// the phases are disjoint sub-intervals that never sum past the whole
/// step, and an idle no-op step costs nothing at all.
#[test]
fn step_reports_account_phase_wall_time() {
    let engine = plain_engine(15);
    let mut s = Scheduler::new(&engine, &opts(2)).unwrap();
    for i in 0..4 {
        s.submit(RequestSpec::new(format!("{i} + 5 ="), 3)).unwrap();
    }
    while !s.is_idle() {
        let r = s.step().unwrap();
        assert!(r.step_ms > 0.0, "a non-idle step took no wall time: {r:?}");
        assert!(r.admission_ms >= 0.0 && r.prefill_ms >= 0.0 && r.decode_ms >= 0.0);
        assert!(
            r.admission_ms + r.prefill_ms + r.decode_ms <= r.step_ms + 1e-6,
            "phase times overflowed the step: {r:?}"
        );
        if r.admitted.is_empty() {
            assert_eq!(r.prefill_ms, 0.0, "prefill billed with nothing admitted: {r:?}");
        }
        if r.decoded_rows == 0 {
            assert_eq!(r.decode_ms, 0.0, "decode billed with no rows fed: {r:?}");
        }
    }
    let r = s.step().unwrap();
    assert_eq!(
        (r.step_ms, r.admission_ms, r.prefill_ms, r.decode_ms),
        (0.0, 0.0, 0.0, 0.0),
        "an idle step billed wall time"
    );
}

/// Under a persistently full batch, admission is FIFO: concatenating the
/// admitted ids across steps reproduces submission order exactly, and
/// nobody is starved.
#[test]
fn admission_is_fifo_under_full_batch() {
    let engine = plain_engine(11);
    let mut s = Scheduler::new(&engine, &opts(2)).unwrap();
    let mut submitted = Vec::new();
    for i in 0..7 {
        // mixed budgets: short requests finish early and free slots while
        // long ones hold theirs — the reuse pattern fixed batches can't do
        let max_new = [2usize, 9, 4][i % 3];
        submitted
            .push(s.submit(RequestSpec::new(format!("{i} + {i} =", i = i % 10), max_new)).unwrap());
    }
    let mut admitted = Vec::new();
    while !s.is_idle() {
        let report = s.step().unwrap();
        assert!(report.admitted.len() <= 2);
        admitted.extend(report.admitted);
    }
    assert_eq!(admitted, submitted, "admission order diverged from submission order");
    assert_eq!(s.take_finished().len(), 7);
}

/// The streaming sink sees every generated token of every request, in
/// generation order, and exactly one finish event per request.
#[test]
fn sink_streams_every_token_in_order() {
    struct VecSink {
        tokens: Rc<RefCell<Vec<(u64, u32)>>>,
        finishes: Rc<RefCell<Vec<u64>>>,
    }
    impl TokenSink for VecSink {
        fn on_token(&mut self, id: u64, token: u32) {
            self.tokens.borrow_mut().push((id, token));
        }
        fn on_finish(&mut self, resp: &SchedResponse) {
            self.finishes.borrow_mut().push(resp.id);
        }
    }
    let engine = plain_engine(13);
    let tokens = Rc::new(RefCell::new(Vec::new()));
    let finishes = Rc::new(RefCell::new(Vec::new()));
    let sink = VecSink { tokens: Rc::clone(&tokens), finishes: Rc::clone(&finishes) };
    let mut s = Scheduler::new(&engine, &opts(2)).unwrap().with_sink(Box::new(sink));
    let mut ids = Vec::new();
    for i in 0..5 {
        ids.push(s.submit(RequestSpec::new(format!("{i} * 2 ="), 6)).unwrap());
    }
    s.run_until_idle().unwrap();
    let responses = s.take_finished();
    // one finish per request, stream count matches each token count
    let mut fin = finishes.borrow().clone();
    fin.sort_unstable();
    assert_eq!(fin, ids);
    let tokens = tokens.borrow();
    for r in &responses {
        let streamed: Vec<u32> =
            tokens.iter().filter(|(id, _)| *id == r.id).map(|(_, t)| *t).collect();
        assert_eq!(streamed.len(), r.tokens, "request {} streamed a different count", r.id);
    }
}

/// Staggered arrivals under a tight batch still decode every prompt
/// bit-identically to a one-shot single-prompt decode: admission waves,
/// slot reuse, and batch composition never leak into a request's tokens.
/// The workload (prompt/output-length mix) comes from the same load
/// generator the serving bench uses; arrivals are virtualized as
/// one-submission-per-step so the test is wall-clock free.
#[test]
fn staggered_arrivals_decode_bit_identically_to_one_shot() {
    let (cfg, store) = merged_tiny(207);
    let engine = Engine::from_store(&cfg, &store, 4).unwrap();
    let spec = LoadSpec {
        n_requests: 9,
        rate_per_sec: 50.0,
        seed: 41,
        task: "arith".into(),
        max_new_mix: vec![3, 7, 12],
    };
    let load = generate_load(&spec).unwrap();
    let mut s = Scheduler::new(&engine, &opts(3)).unwrap();
    let mut pending = load.iter();
    let mut ids: Vec<(u64, &lota_qaf::sched::LoadRequest)> = Vec::new();
    // drip one arrival per step while the batch is busy with earlier ones
    loop {
        if let Some(req) = pending.next() {
            ids.push((s.submit(RequestSpec::new(req.prompt.as_str(), req.max_new)).unwrap(), req));
        } else if s.is_idle() {
            break;
        }
        s.step().unwrap();
    }
    let responses = s.take_finished();
    assert_eq!(responses.len(), 9);
    for (id, req) in ids {
        let got = responses.iter().find(|r| r.id == id).unwrap();
        let want = greedy_decode(&engine, &[req.prompt.clone()], req.max_new).unwrap();
        assert_eq!(got.text, want[0].text, "request {id} diverged from one-shot decode");
        assert_eq!(got.tokens, want[0].tokens);
    }
}

/// The redesign's parity contract: with one priority class, no deadlines,
/// and an unbounded queue, the overload-control machinery must be
/// invisible — step-for-step admission order, finish order, and decoded
/// bytes all `assert_eq!` the plain-FIFO run on identical weights. This
/// pins the "bitwise no-op at defaults" clause of the RequestSpec
/// redesign, not just end-text equality.
#[test]
fn one_class_no_deadline_is_bitwise_identical_to_plain_fifo() {
    let (cfg, store) = merged_tiny(212);
    let spec = LoadSpec {
        n_requests: 8,
        rate_per_sec: 50.0,
        seed: 43,
        task: "arith".into(),
        max_new_mix: vec![2, 6, 11],
    };
    let load = generate_load(&spec).unwrap();
    // explicit overload-control defaults, spelled out so a future default
    // change cannot silently re-point this pin
    let explicit = SchedOptions {
        max_batch: 3,
        priority_classes: 1,
        submit_queue_cap: 0,
        default_deadline_ms: None,
        ..SchedOptions::default()
    };
    let mut runs = Vec::new();
    for options in [opts(3), explicit] {
        let engine = Engine::from_store(&cfg, &store, 4).unwrap();
        let mut s = Scheduler::new(&engine, &options).unwrap();
        let mut pending = load.iter();
        let mut trace = Vec::new();
        loop {
            if let Some(req) = pending.next() {
                s.submit(RequestSpec::new(req.prompt.as_str(), req.max_new)).unwrap();
            } else if s.is_idle() {
                break;
            }
            let r = s.step().unwrap();
            trace.push((r.admitted, r.finished, r.shed, r.queue_depth));
        }
        let mut finished: Vec<(u64, String, usize, FinishReason)> = s
            .take_finished()
            .into_iter()
            .map(|r| (r.id, r.text, r.tokens, r.reason))
            .collect();
        finished.sort_by_key(|(id, ..)| *id);
        runs.push((trace, finished));
    }
    assert_eq!(runs[0].0, runs[1].0, "step-level schedule diverged at defaults");
    assert_eq!(runs[0].1, runs[1].1, "decoded outputs diverged at defaults");
    assert!(runs[0].0.iter().all(|(_, _, shed, _)| shed.is_empty()));
}
