//! Shared test-support for the parity suites: the synthetic merged
//! checkpoint both `backend_parity` and `engine_parity` pin against.
//! Cargo compiles this module into each test binary that declares
//! `mod common;` — it is not a test target itself.

use lota_qaf::adapter::{lota_merge, TernaryAdapter};
use lota_qaf::config::{preset, ModelConfig};
use lota_qaf::model::{self, ParamStore};
use lota_qaf::quant::rtn_quantize;
use lota_qaf::tensor::{Rng, Tensor};

/// A merged tiny checkpoint: quantize, then fold non-trivial ternary
/// adapters into the grid so the parity surface isn't the identity merge.
pub fn merged_tiny(seed: u64) -> (ModelConfig, ParamStore) {
    let cfg = preset("tiny").unwrap();
    let mut rng = Rng::new(seed);
    let fp = model::init_fp(&cfg, &mut rng);
    let mut store =
        model::quantize_store(&cfg, &fp, |_, _, w| Ok(rtn_quantize(w, cfg.group_size, 4)))
            .unwrap();
    for (slot, din, dout) in cfg.slots() {
        for li in 0..cfg.n_layers {
            let ql = model::quant_layer(&cfg, &store, slot, li, 4).unwrap();
            let mut ta = TernaryAdapter::init(din, dout, cfg.rank, &mut rng);
            ta.b = Tensor::new(
                &[cfg.rank, dout],
                (0..cfg.rank * dout).map(|_| rng.below(3) as f32 - 1.0).collect(),
            );
            let merged = lota_merge(&ql, &ta, 0.75 * cfg.rank as f32);
            model::set_quant_layer(&mut store, slot, li, &merged).unwrap();
        }
    }
    (cfg, store)
}
