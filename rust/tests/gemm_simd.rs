//! SIMD/scalar packed-GEMM parity, pinned **bit-identical** — the
//! integration-level statement of the lane-ordered accumulation contract
//! in `engine::simd`.
//!
//! Every kernel the dispatcher can select (AVX2 where the host has it,
//! the portable 8-lane fallback, the scalar reference) must produce the
//! same `f32` bits on the same inputs, across bit widths, group sizes
//! with 8-lane remainder tails, batch shapes, and thread counts — that
//! bitwise agreement is what lets the engine/sched/paged parity suites
//! keep holding `assert_eq!` whatever hardware CI lands on. Artifact-free;
//! runs in the CI `build` job on every PR (and the whole `engine_parity`
//! suite re-runs under `LOTA_GEMM_KERNEL=scalar` as the fallback leg).
//!
//! Tests in this binary run under one mutex: the dispatch-bypass test
//! watches a process-global counter of SIMD block executions, which would
//! race against concurrently running matmuls from sibling tests.

use std::sync::Mutex;

use lota_qaf::config::GemmKernel;
use lota_qaf::engine::{
    matmul_packed_dispatch, matmul_packed_opts, simd, Engine, GemmDispatch, PackedLinear,
};
use lota_qaf::model;
use lota_qaf::quant::rtn_quantize;
use lota_qaf::tensor::{Rng, Tensor};

static LOCK: Mutex<()> = Mutex::new(());

fn locked() -> std::sync::MutexGuard<'static, ()> {
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn setup(
    seed: u64,
    m: usize,
    din: usize,
    dout: usize,
    gs: usize,
    bits: u32,
) -> (Tensor, PackedLinear) {
    let mut rng = Rng::new(seed);
    let w = Tensor::new(&[din, dout], rng.normal_vec(din * dout, 0.1));
    let ql = rtn_quantize(&w, gs, bits);
    let x = Tensor::new(&[m, din], rng.normal_vec(m * din, 1.0));
    (x, PackedLinear::from_quantized(&ql).unwrap())
}

/// All dispatches this host can actually run (AVX2 only where detected).
fn available_dispatches() -> Vec<GemmDispatch> {
    let mut d = vec![GemmDispatch::Scalar, GemmDispatch::Portable];
    if simd::resolve(GemmKernel::Simd) == GemmDispatch::Avx2 {
        d.push(GemmDispatch::Avx2);
    }
    d
}

#[test]
fn kernels_bitwise_identical_across_bit_widths_and_group_tails() {
    let _g = locked();
    // group sizes chosen so the 8-lane split sees: no tail (gs = 16, 32),
    // tails of 4 (gs = 12, 20), and an all-tail group (gs = 6 < lanes)
    for bits in [2u32, 3, 4] {
        for (m, din, dout, gs) in [
            (1, 48, 20, 16),
            (5, 96, 33, 32),
            (3, 60, 24, 12),
            (4, 80, 17, 20),
            (2, 36, 9, 6),
        ] {
            let (x, pl) = setup(bits as u64 * 1000 + gs as u64, m, din, dout, gs, bits);
            let scalar = matmul_packed_dispatch(&x, &pl, GemmDispatch::Scalar, Some(1));
            for d in available_dispatches() {
                let y = matmul_packed_dispatch(&x, &pl, d, Some(1));
                assert_eq!(
                    y, scalar,
                    "kernel {} diverged from scalar (bits={bits} m={m} din={din} \
                     dout={dout} gs={gs})",
                    d.label()
                );
            }
        }
    }
}

#[test]
fn single_row_calls_match_batched_rows_under_every_kernel() {
    let _g = locked();
    // the cached-decode contract, per kernel: any row subset reproduces
    // the full batch's bits exactly
    let (x, pl) = setup(7, 6, 64, 40, 16, 4);
    let dout = pl.dout();
    for d in available_dispatches() {
        let full = matmul_packed_dispatch(&x, &pl, d, Some(1));
        for mi in 0..x.rows() {
            let one = Tensor::new(&[1, x.cols()], x.row(mi).to_vec());
            let y = matmul_packed_dispatch(&one, &pl, d, Some(1));
            assert_eq!(
                y.data(),
                &full.data()[mi * dout..(mi + 1) * dout],
                "kernel {} row {mi}",
                d.label()
            );
        }
    }
}

#[test]
fn thread_fanout_never_changes_bits_under_any_kernel() {
    let _g = locked();
    let (x, pl) = setup(9, 11, 64, 50, 20, 3);
    for d in available_dispatches() {
        let serial = matmul_packed_dispatch(&x, &pl, d, Some(1));
        for threads in [2usize, 3, 8, 64] {
            let par = matmul_packed_dispatch(&x, &pl, d, Some(threads));
            assert_eq!(par, serial, "kernel {} threads {threads}", d.label());
        }
    }
}

#[test]
fn requested_kernels_resolve_and_agree() {
    let _g = locked();
    let (x, pl) = setup(13, 4, 48, 24, 12, 2);
    let scalar = matmul_packed_opts(&x, &pl, GemmKernel::Scalar, Some(1));
    let simd_y = matmul_packed_opts(&x, &pl, GemmKernel::Simd, Some(1));
    let auto_y = matmul_packed_opts(&x, &pl, GemmKernel::Auto, Some(1));
    assert_eq!(simd_y, scalar);
    assert_eq!(auto_y, scalar);
    // an explicit simd request never resolves to the scalar reference
    assert!(simd::resolve(GemmKernel::Simd).is_simd());
    assert_eq!(simd::resolve(GemmKernel::Scalar), GemmDispatch::Scalar);
}

#[test]
fn forced_scalar_override_bypasses_the_simd_path() {
    let _g = locked();
    let (x, pl) = setup(17, 3, 64, 32, 16, 4);
    // forced scalar: the SIMD block counter must not move — identical
    // *bits* alone wouldn't prove the override reached the dispatcher
    let before = simd::simd_blocks_run();
    for threads in [1usize, 4] {
        matmul_packed_opts(&x, &pl, GemmKernel::Scalar, Some(threads));
    }
    assert_eq!(
        simd::simd_blocks_run(),
        before,
        "a scalar-forced matmul executed a SIMD block"
    );
    // forced simd: the counter must advance (portable counts as SIMD —
    // the point is which code path ran, not which ISA)
    let before = simd::simd_blocks_run();
    matmul_packed_opts(&x, &pl, GemmKernel::Simd, Some(1));
    assert!(simd::simd_blocks_run() > before, "a simd-forced matmul never ran a SIMD block");
}

#[test]
fn engine_level_override_switches_the_whole_forward() {
    let _g = locked();
    let cfg = lota_qaf::config::preset("tiny").unwrap();
    let mut rng = Rng::new(23);
    let fp = model::init_fp(&cfg, &mut rng);
    let store = model::quantize_store(&cfg, &fp, |_, _, w| {
        Ok(rtn_quantize(w, cfg.group_size, 4))
    })
    .unwrap();
    let mut scalar_eng = Engine::from_store(&cfg, &store, 4).unwrap();
    scalar_eng.set_gemm_kernel(GemmKernel::Scalar);
    assert_eq!(scalar_eng.gemm_kernel_label(), "scalar");
    let mut simd_eng = Engine::from_store(&cfg, &store, 4).unwrap();
    simd_eng.set_gemm_kernel(GemmKernel::Simd);
    assert_ne!(simd_eng.gemm_kernel_label(), "scalar");

    let tokens = Tensor::new(&[2, 9], (0..18).map(|i| (i % cfg.vocab) as f32).collect());
    let ls = scalar_eng.forward(&tokens).unwrap();
    let lv = simd_eng.forward(&tokens).unwrap();
    // a full transformer forward, layer norms and attention included,
    // bit-identical across kernels — the property every serving parity
    // pin in this repo stands on
    assert_eq!(ls, lv);

    // and the scalar engine really avoids SIMD blocks end to end
    let before = simd::simd_blocks_run();
    scalar_eng.forward(&tokens).unwrap();
    assert_eq!(simd::simd_blocks_run(), before);
}
