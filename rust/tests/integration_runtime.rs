//! PJRT integration tests: load the AOT artifacts and prove the full
//! cross-language stack — Pallas kernels running under the Rust CPU
//! client, the training step moving adapters, and the system-level
//! **lossless merge invariant**: merged-model logits ≡ adapter-model
//! logits through two *different* HLO programs.
//!
//! These tests share one Runtime (PJRT clients are heavyweight) and run
//! serially within each test; `--test-threads` does not matter because the
//! Runtime is behind a OnceLock.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::OnceLock;

use lota_qaf::adapter::lota_merge;
use lota_qaf::config::{preset, step_batch, ExperimentConfig, Method};
use lota_qaf::coordinator::{self, train};
use lota_qaf::data::{corpus, lm_batch, sft_batch, Example};
use lota_qaf::model::{self, ParamStore, SLOTS};
use lota_qaf::quant::rtn_quantize;
use lota_qaf::runtime::Runtime;
use lota_qaf::tensor::{Rng, Tensor};

fn runtime() -> &'static Runtime {
    static RT: OnceLock<Runtime> = OnceLock::new();
    RT.get_or_init(|| {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        Runtime::new(&dir).expect("artifacts missing — run `make artifacts`")
    })
}

/// Build a deterministic quantized tiny model + ternary adapters.
fn tiny_setup(seed: u64) -> (lota_qaf::config::ModelConfig, ParamStore) {
    let cfg = preset("tiny").unwrap();
    let mut rng = Rng::new(seed);
    let fp = model::init_fp(&cfg, &mut rng);
    let mut store =
        model::quantize_store(&cfg, &fp, |_, _, w| Ok(rtn_quantize(w, cfg.group_size, 4)))
            .unwrap();
    model::init_adapters(&cfg, Method::LotaQaf, &mut rng, &mut store);
    (cfg, store)
}

fn rand_tokens(cfg: &lota_qaf::config::ModelConfig, b: usize, seed: u64) -> Tensor {
    let mut rng = Rng::new(seed);
    Tensor::new(
        &[b, cfg.seq_len],
        (0..b * cfg.seq_len).map(|_| rng.below(cfg.vocab) as f32).collect(),
    )
}

// ---------------------------------------------------------------------------
// Kernel artifacts: the L1 Pallas kernels, lowered and executed via PJRT

#[test]
fn kernel_qmm_runs_and_matches_host() {
    let rt = runtime();
    let mut rng = Rng::new(1);
    let (m, din, dout, g) = (16, 64, 128, 4);
    let x = Tensor::new(&[m, din], rng.normal_vec(m * din, 1.0));
    let w_int = Tensor::new(&[din, dout], (0..din * dout).map(|_| rng.below(16) as f32).collect());
    let scales = Tensor::new(&[g, dout], (0..g * dout).map(|_| rng.uniform() * 0.1 + 0.01).collect());
    let zeros = Tensor::new(&[g, dout], rng.normal_vec(g * dout, 0.1));
    let out = rt.run("kernel_qmm", &[&x, &w_int, &scales, &zeros]).unwrap();
    let w = lota_qaf::quant::dequant(&w_int, &scales, &zeros, din / g);
    let want = lota_qaf::tensor::linalg::matmul(&x, &w);
    assert!(
        out[0].allclose(&want, 1e-4, 1e-4),
        "pallas qmm vs host: {}",
        out[0].max_abs_diff(&want)
    );
}

#[test]
fn kernel_ternary_runs_and_matches_host_merge() {
    let rt = runtime();
    let mut rng = Rng::new(2);
    let (din, dout, g, r) = (64, 128, 4, 8);
    let w = Tensor::new(&[din, dout], rng.normal_vec(din * dout, 0.1));
    let ql = rtn_quantize(&w, din / g, 4);
    let a = Tensor::new(&[din, r], (0..din * r).map(|_| rng.below(3) as f32 - 1.0).collect());
    let b = Tensor::new(&[r, dout], (0..r * dout).map(|_| rng.below(3) as f32 - 1.0).collect());
    let omega = Tensor::from_scalar(6.0);
    let out = rt
        .run("kernel_ternary", &[&a, &b, &ql.w_int, &ql.scales, &ql.zeros, &omega])
        .unwrap();
    let ta = lota_qaf::adapter::TernaryAdapter::from_parts(a, b).unwrap();
    let merged = lota_merge(&ql, &ta, 6.0);
    // EXACT integer-grid agreement between the Pallas kernel (through
    // PJRT) and the Rust host merge:
    assert_eq!(out[0], merged.w_int);
    assert!(out[1].allclose(&merged.zeros, 1e-5, 1e-6));
}

#[test]
fn kernel_tsign_runs_and_matches_host() {
    let rt = runtime();
    let mut rng = Rng::new(3);
    let (rows, cols) = (64, 8);
    let a = Tensor::new(&[rows, cols], (0..rows * cols).map(|_| rng.below(3) as f32 - 1.0).collect());
    let g = Tensor::new(&[rows, cols], rng.normal_vec(rows * cols, 1e-3));
    let kf = Tensor::from_scalar(0.05);
    let out = rt.run("kernel_tsign", &[&a, &g, &kf]).unwrap();
    let (want, _) = lota_qaf::optim::tsign_update_host(&a, &g, 0.05);
    assert_eq!(out[0], want, "t-SignSGD kernel diverges from host reference");
}

// ---------------------------------------------------------------------------
// Full-model invariants through the lowered graphs

#[test]
fn lossless_merge_invariant_end_to_end() {
    let rt = runtime();
    let (cfg, mut store) = tiny_setup(10);
    // give B_T non-trivial ternary values so the merge actually moves grids
    let mut rng = Rng::new(11);
    for slot in SLOTS {
        let name = format!("ta_{slot}_b");
        let t = store.get(&name).unwrap();
        let vals: Vec<f32> = (0..t.len()).map(|_| rng.below(3) as f32 - 1.0).collect();
        let shape = t.shape().to_vec();
        store.insert(&name, Tensor::new(&shape, vals));
    }
    let omega = 0.75 * cfg.rank as f32;
    let b = step_batch(&cfg.name);
    let tokens = rand_tokens(&cfg, b, 12);

    // (1) adapter-applied forward through the lota graph
    let exe_lota = rt.load("fwd_lota_tiny_w4").unwrap();
    let logits_adapter =
        coordinator::run_forward(rt, &exe_lota, &store, &tokens, Some(omega)).unwrap();

    // (2) host-side merge, then the merged graph
    let exp = ExperimentConfig {
        method: Method::LotaQaf,
        n_bits: 4,
        omega_frac: 0.75,
        ..Default::default()
    };
    let mut merged = store.clone();
    let err = train::merge_into_store(&cfg, &exp, &mut merged).unwrap();
    assert_eq!(err, 0.0, "LoTA merge must be exactly lossless");
    let exe_merged = rt.load("fwd_merged_tiny").unwrap();
    let logits_merged =
        coordinator::run_forward(rt, &exe_merged, &merged, &tokens, None).unwrap();

    // identical representation ⇒ logits agree to f32 reassociation noise
    let diff = logits_adapter.max_abs_diff(&logits_merged);
    assert!(diff < 2e-4, "lossless merge violated: logit diff {diff}");
}

#[test]
fn lora_merge_is_visibly_lossy_end_to_end() {
    let rt = runtime();
    let cfg = preset("tiny").unwrap();
    let mut rng = Rng::new(20);
    let fp = model::init_fp(&cfg, &mut rng);
    let mut store =
        model::quantize_store(&cfg, &fp, |_, _, w| Ok(rtn_quantize(w, cfg.group_size, 4)))
            .unwrap();
    model::init_adapters(&cfg, Method::Lora, &mut rng, &mut store);
    // non-trivial B so the update is non-zero
    for slot in SLOTS {
        let name = format!("lo_{slot}_b");
        let t = store.get(&name).unwrap();
        let shape = t.shape().to_vec();
        let n = t.len();
        store.insert(&name, Tensor::new(&shape, rng.normal_vec(n, 0.05)));
    }
    let b = step_batch(&cfg.name);
    let tokens = rand_tokens(&cfg, b, 21);

    let exe_lora = rt.load("fwd_lora_tiny").unwrap();
    let logits_adapter =
        coordinator::run_forward(rt, &exe_lora, &store, &tokens, None).unwrap();

    let exp = ExperimentConfig { method: Method::Lora, n_bits: 4, ..Default::default() };
    let mut merged = store.clone();
    let err = train::merge_into_store(&cfg, &exp, &mut merged).unwrap();
    assert!(err > 1e-4, "requantization error should be visible, got {err}");
    let exe_merged = rt.load("fwd_merged_tiny").unwrap();
    let logits_merged =
        coordinator::run_forward(rt, &exe_merged, &merged, &tokens, None).unwrap();
    let diff = logits_adapter.max_abs_diff(&logits_merged);
    assert!(diff > 1e-3, "LoRA requant merge should move logits, diff {diff}");
}

#[test]
fn qalora_merge_lossless_end_to_end() {
    let rt = runtime();
    let cfg = preset("tiny").unwrap();
    let mut rng = Rng::new(30);
    let fp = model::init_fp(&cfg, &mut rng);
    let mut store =
        model::quantize_store(&cfg, &fp, |_, _, w| Ok(rtn_quantize(w, cfg.group_size, 4)))
            .unwrap();
    model::init_adapters(&cfg, Method::QaLora, &mut rng, &mut store);
    for slot in SLOTS {
        let name = format!("qa_{slot}_b");
        let t = store.get(&name).unwrap();
        let shape = t.shape().to_vec();
        let n = t.len();
        store.insert(&name, Tensor::new(&shape, rng.normal_vec(n, 0.05)));
    }
    let b = step_batch(&cfg.name);
    let tokens = rand_tokens(&cfg, b, 31);

    let exe_qa = rt.load("fwd_qalora_tiny").unwrap();
    let logits_adapter =
        coordinator::run_forward(rt, &exe_qa, &store, &tokens, None).unwrap();
    let exp = ExperimentConfig { method: Method::QaLora, n_bits: 4, ..Default::default() };
    let mut merged = store.clone();
    train::merge_into_store(&cfg, &exp, &mut merged).unwrap();
    let exe_merged = rt.load("fwd_merged_tiny").unwrap();
    let logits_merged =
        coordinator::run_forward(rt, &exe_merged, &merged, &tokens, None).unwrap();
    let diff = logits_adapter.max_abs_diff(&logits_merged);
    assert!(diff < 2e-4, "QA-LoRA merge should be lossless, diff {diff}");
}

// ---------------------------------------------------------------------------
// Training-step artifacts

#[test]
fn lota_step_moves_adapters_and_reduces_loss() {
    let rt = runtime();
    let (cfg, mut store) = tiny_setup(40);
    let exe = rt.load("step_lota_tiny_w4").unwrap();
    let b = step_batch(&cfg.name);
    let examples: Vec<Example> = {
        let mut rng = Rng::new(41);
        (0..b)
            .map(|_| {
                let (p, c) = corpus::sample_recovery_example(&mut rng);
                Example { prompt: p, completion: c }
            })
            .collect()
    };
    let batch = sft_batch(&examples, b, cfg.seq_len);
    let mut scalars = BTreeMap::new();
    scalars.insert("omega".to_string(), Tensor::from_scalar(4.0));
    scalars.insert("keep_frac".to_string(), Tensor::from_scalar(0.05));

    let before = store.get("ta_wq_b").unwrap().clone();
    let mut losses = Vec::new();
    for _ in 0..6 {
        let loss = coordinator::run_step(rt, &exe, &mut store, None, None, &batch, &scalars)
            .unwrap();
        losses.push(loss);
    }
    // adapters stayed ternary
    for slot in SLOTS {
        for suffix in ["a", "b"] {
            let t = store.get(&format!("ta_{slot}_{suffix}")).unwrap();
            assert!(
                t.data().iter().all(|v| [-1.0, 0.0, 1.0].contains(v)),
                "ta_{slot}_{suffix} left ternary domain"
            );
        }
    }
    // something moved, and the fixed-batch loss went down
    let after = store.get("ta_wq_b").unwrap();
    assert!(before.max_abs_diff(after) > 0.0, "no adapter movement");
    assert!(
        losses.last().unwrap() < losses.first().unwrap(),
        "loss did not improve: {losses:?}"
    );
}

#[test]
fn pretrain_step_reduces_loss() {
    let rt = runtime();
    let cfg = preset("tiny").unwrap();
    let mut rng = Rng::new(50);
    let mut store = model::init_fp(&cfg, &mut rng);
    let mut m = ParamStore::new();
    let mut v = ParamStore::new();
    for n in model::fp_names() {
        let shape = store.get(&n).unwrap().shape().to_vec();
        m.insert(&n, Tensor::zeros(&shape));
        v.insert(&n, Tensor::zeros(&shape));
    }
    let exe = rt.load("pretrain_step_tiny").unwrap();
    let b = step_batch(&cfg.name);
    let docs: Vec<String> = (0..b).map(|_| corpus::sample_document(&mut rng)).collect();
    let batch = lm_batch(&docs, b, cfg.seq_len);
    let mut losses = Vec::new();
    for t in 1..=5 {
        let mut scalars = BTreeMap::new();
        scalars.insert("lr".to_string(), Tensor::from_scalar(1e-3));
        scalars.insert("step".to_string(), Tensor::from_scalar(t as f32));
        let loss = coordinator::run_step(
            rt,
            &exe,
            &mut store,
            Some(&mut m),
            Some(&mut v),
            &batch,
            &scalars,
        )
        .unwrap();
        losses.push(loss);
    }
    assert!(losses[4] < losses[0], "pretraining no progress: {losses:?}");
}

#[test]
fn adamw_step_artifacts_run_for_baselines() {
    let rt = runtime();
    let cfg = preset("tiny").unwrap();
    for (method, artifact) in [(Method::Lora, "step_lora_tiny"), (Method::QaLora, "step_qalora_tiny")]
    {
        let mut rng = Rng::new(60);
        let fp = model::init_fp(&cfg, &mut rng);
        let mut store = model::quantize_store(&cfg, &fp, |_, _, w| {
            Ok(rtn_quantize(w, cfg.group_size, 4))
        })
        .unwrap();
        model::init_adapters(&cfg, method, &mut rng, &mut store);
        let mut m = ParamStore::new();
        let mut v = ParamStore::new();
        for n in model::adapter_names(method) {
            let shape = store.get(&n).unwrap().shape().to_vec();
            m.insert(&n, Tensor::zeros(&shape));
            v.insert(&n, Tensor::zeros(&shape));
        }
        let exe = rt.load(artifact).unwrap();
        let b = step_batch(&cfg.name);
        let examples: Vec<Example> = (0..b)
            .map(|_| {
                let (p, c) = corpus::sample_recovery_example(&mut rng);
                Example { prompt: p, completion: c }
            })
            .collect();
        let batch = sft_batch(&examples, b, cfg.seq_len);
        let mut losses = Vec::new();
        for t in 1..=5 {
            let mut scalars = BTreeMap::new();
            scalars.insert("lr".to_string(), Tensor::from_scalar(5e-3));
            scalars.insert("step".to_string(), Tensor::from_scalar(t as f32));
            losses.push(
                coordinator::run_step(
                    rt,
                    &exe,
                    &mut store,
                    Some(&mut m),
                    Some(&mut v),
                    &batch,
                    &scalars,
                )
                .unwrap(),
            );
        }
        assert!(
            losses[4] < losses[0],
            "{artifact}: no progress {losses:?}"
        );
    }
}

#[test]
fn manifest_shapes_match_rust_presets() {
    let rt = runtime();
    let cfg = preset("tiny").unwrap();
    let spec = rt.manifest().get("fwd_merged_tiny").unwrap();
    // embed input must be (vocab, d_model)
    let embed = spec.inputs.iter().find(|i| i.name == "embed").unwrap();
    assert_eq!(embed.shape, vec![cfg.vocab, cfg.d_model]);
    let tokens = spec.inputs.iter().find(|i| i.name == "tokens").unwrap();
    assert_eq!(tokens.shape[1], cfg.seq_len);
    let wint = spec.inputs.iter().find(|i| i.name == "q_wq_int").unwrap();
    assert_eq!(wint.shape, vec![cfg.n_layers, cfg.d_model, cfg.d_model]);
}
