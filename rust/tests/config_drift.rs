//! Example-config drift gate — artifact-free, runs in CI.
//!
//! Every TOML under `examples/` is documentation the parser is never
//! asked about: a key rename in `ExperimentConfig::from_toml` (or a typo
//! in an example) silently turns the shipped config into one that parses
//! to defaults. This suite loads each example through the real parsing
//! path — `TomlDoc` → `ExperimentConfig` → `AdapterRegistry` → model
//! preset lookup — so any drift between the docs and the code fails the
//! build instead of a user's first `lota serve`.

use std::fs;
use std::path::PathBuf;

use lota_qaf::config::{preset, Backend, ExperimentConfig, TomlDoc};
use lota_qaf::serve::AdapterRegistry;

fn examples_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("examples")
}

fn example_tomls() -> Vec<PathBuf> {
    let mut found: Vec<PathBuf> = fs::read_dir(examples_dir())
        .expect("examples/ directory missing")
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| p.extension().is_some_and(|x| x == "toml"))
        .collect();
    found.sort();
    found
}

/// Every shipped example must travel the full config path without error,
/// and must name a model preset that actually exists.
#[test]
fn every_example_toml_parses_through_the_real_config_path() {
    let tomls = example_tomls();
    assert!(tomls.len() >= 2, "examples/ lost its TOMLs: found {tomls:?}");
    for path in &tomls {
        let src = fs::read_to_string(path).unwrap();
        let doc = TomlDoc::parse(&src)
            .unwrap_or_else(|e| panic!("{}: TOML parse failed: {e:#}", path.display()));
        let exp = ExperimentConfig::from_toml(&doc)
            .unwrap_or_else(|e| panic!("{}: config rejected: {e:#}", path.display()));
        AdapterRegistry::from_pairs(&exp.adapters)
            .unwrap_or_else(|e| panic!("{}: [adapters] rejected: {e:#}", path.display()));
        preset(&exp.model)
            .unwrap_or_else(|e| panic!("{}: unknown model preset: {e:#}", path.display()));
    }
}

/// The multi-adapter example must keep describing a runnable multi-adapter
/// deployment: scheduler on, native backend, and an [adapters] table whose
/// alphabetical key order (= adapter id order) is what its comments claim.
#[test]
fn serve_adapters_example_stays_a_runnable_adapter_deployment() {
    let src = fs::read_to_string(examples_dir().join("serve_adapters.toml")).unwrap();
    let exp = ExperimentConfig::from_toml(&TomlDoc::parse(&src).unwrap()).unwrap();
    assert_eq!(exp.backend, Backend::Native, "adapters serve on the native backend only");
    assert!(exp.sched.is_some(), "multi-adapter serving routes through the scheduler");
    let reg = AdapterRegistry::from_pairs(&exp.adapters).unwrap();
    assert!(reg.len() >= 2, "the example should demo an actual adapter mix");
    // alphabetical [adapters] keys: "de" registers first -> adapter id 1
    assert_eq!(reg.specs()[0].name, "de");
    assert_eq!(reg.specs()[1].name, "fr");
    for spec in reg.specs() {
        assert!(
            spec.source.starts_with("synthetic:"),
            "example adapter {:?} points at {:?} — shipped examples must not \
             depend on checkpoint files existing",
            spec.name,
            spec.source
        );
    }
}

/// The scheduled-serving example keeps its [sched] table parseable and
/// non-default-shaped (it exists to show the knobs) — including the
/// overload-control keys, which must reach SchedConfig with the values
/// the comments document rather than silently parsing to defaults.
#[test]
fn serve_sched_example_keeps_its_sched_table() {
    let src = fs::read_to_string(examples_dir().join("serve_sched.toml")).unwrap();
    let exp = ExperimentConfig::from_toml(&TomlDoc::parse(&src).unwrap()).unwrap();
    assert_eq!(exp.backend, Backend::Native);
    let sched = exp.sched.expect("serve_sched.toml stopped enabling the scheduler");
    assert_eq!(
        sched.priority_classes, 2,
        "the example should demo priority admission (and 2 is what its comments claim)"
    );
    assert_eq!(sched.submit_queue_cap, 64, "the example documents a bounded submit queue");
    assert_eq!(
        sched.default_deadline_ms, 0,
        "the example documents deadline shedding as off by default"
    );
}
