//! Observability suite — artifact-free, runs in CI next to `sched`.
//!
//! Pins the three contracts `src/obs/` makes:
//!
//! 1. **Inert when disabled** — a scheduler with no tracer, a
//!    `NoopTracer`, and a `RecordingTracer` produce bitwise-identical
//!    generations and decode accounting, and an idle step records no
//!    events at all.
//! 2. **Complete span chains** — every `begin` has a matching `end` in
//!    strict per-track LIFO order, whatever the lifecycle throws at it
//!    (cancellation while queued, cancellation mid-decode, paged
//!    admission denial, slot reuse). The single-request step sequence is
//!    pinned event-for-event as a golden list.
//! 3. **One clock** — span durations reconcile exactly with the
//!    `SchedStats` histograms for the same run, because emission sites
//!    share the scheduler's `Instant`s; and the Chrome-trace JSON export
//!    round-trips through the crate's own parser with balanced B/E
//!    stacks per (pid, tid).
//!
//! The engine profiler is held to the same three, one notch harder:
//! attaching a `Profiler` is bitwise inert on scheduler outputs, each
//! window's phase segments tile it exactly and its wall-time *bit-equals*
//! the `StepReport.prefill_ms`/`decode_ms` it encloses (`assert_eq!` on
//! f64 — no tolerance), and its pid-3 engine spans nest inside the
//! scheduler's forward spans in the shared Chrome export.

use std::collections::HashMap;

use lota_qaf::config::Json;
use lota_qaf::engine::Engine;
use lota_qaf::model;
use lota_qaf::obs::{
    chrome_trace_json, write_chrome_trace, EventKind, ForwardPhase, NoopTracer, PhaseKind,
    Profiler, RecordingTracer, TraceEvent, Track, STEP_TID,
};
use lota_qaf::quant::rtn_quantize;
use lota_qaf::sched::{RequestSpec, RequestState, SchedOptions, Scheduler};
use lota_qaf::tensor::Rng;

fn plain_engine(seed: u64) -> Engine {
    let cfg = lota_qaf::config::preset("tiny").unwrap();
    let mut rng = Rng::new(seed);
    let fp = model::init_fp(&cfg, &mut rng);
    let store = model::quantize_store(&cfg, &fp, |_, _, w| {
        Ok(rtn_quantize(w, cfg.group_size, 4))
    })
    .unwrap();
    Engine::from_store(&cfg, &store, 4).unwrap()
}

fn opts(max_batch: usize) -> SchedOptions {
    // default (paged) layout — tracing covers what serving actually ships
    SchedOptions { max_batch, ..SchedOptions::default() }
}

/// Collapse events to the comparable part: track, phase letter, name.
/// Timestamps and counter values are run-dependent; the *sequence* is
/// what determinism and the golden test pin.
fn sig(events: &[TraceEvent]) -> Vec<(Track, char, &'static str)> {
    events
        .iter()
        .map(|e| {
            let ph = match e.kind {
                EventKind::Begin => 'B',
                EventKind::End => 'E',
                EventKind::Counter(_) => 'C',
            };
            (e.track, ph, e.name)
        })
        .collect()
}

/// Every `end` must close the innermost open span of the same name on
/// its track, and every track must end with its stack empty.
fn assert_balanced(events: &[TraceEvent]) {
    let mut stacks: HashMap<Track, Vec<&'static str>> = HashMap::new();
    for e in events {
        match e.kind {
            EventKind::Begin => stacks.entry(e.track).or_default().push(e.name),
            EventKind::End => {
                let top = stacks.get_mut(&e.track).and_then(|s| s.pop());
                assert_eq!(
                    top,
                    Some(e.name),
                    "end of {:?} on {:?} did not match the innermost open span",
                    e.name,
                    e.track
                );
            }
            EventKind::Counter(_) => {}
        }
    }
    for (track, stack) in stacks {
        assert!(stack.is_empty(), "track {track:?} left spans open: {stack:?}");
    }
}

/// A single one-token request admits, prefills, finishes, and releases
/// in one step — the exact event sequence is the subsystem's golden
/// contract. Counter values the step determines exactly are pinned too.
#[test]
fn golden_span_sequence_for_a_one_token_request() {
    let engine = plain_engine(17);
    let rec = RecordingTracer::new();
    let mut s = Scheduler::new(&engine, &opts(2)).unwrap().with_tracer(Box::new(rec.clone()));
    let id = s.submit(RequestSpec::new("1 + 2 =", 1)).unwrap();
    s.step().unwrap();
    assert!(s.is_idle());

    let r = Track::Request(id);
    let sc = Track::Scheduler;
    // max_new = 1 finishes on its admission step whether the first pick
    // is a token or EOS (apply_pick closes the phase span before the
    // finish check), so this sequence is seed-independent
    let want = vec![
        (r, 'B', "request"),
        (r, 'B', "queued"),
        (sc, 'B', "step"),
        (sc, 'B', "admission"),
        (r, 'E', "queued"),
        (r, 'B', "prefill"),
        (sc, 'E', "admission"),
        (sc, 'B', "prefill_forward"),
        (r, 'E', "prefill"),
        (sc, 'E', "prefill_forward"),
        (sc, 'B', "kv_release"),
        (sc, 'E', "kv_release"),
        (r, 'E', "request"),
        (sc, 'C', "queue_depth"),
        (sc, 'C', "occupancy"),
        (sc, 'C', "decoded_rows"),
        (sc, 'C', "admission_denied_total"),
        (sc, 'C', "kv_blocks_in_use"),
        (sc, 'C', "kv_allocs_total"),
        (sc, 'C', "kv_frees_total"),
        (sc, 'C', "kv_alloc_ms_total"),
        (sc, 'E', "step"),
    ];
    let events = rec.events();
    assert_eq!(sig(&events), want);
    assert_balanced(&events);
    // emission order is timestamp order
    assert!(events.windows(2).all(|w| w[0].ts_us <= w[1].ts_us));

    let val = |name: &str| {
        events
            .iter()
            .find_map(|e| match e.kind {
                EventKind::Counter(v) if e.name == name => Some(v),
                _ => None,
            })
            .unwrap()
    };
    assert_eq!(val("queue_depth"), 0.0);
    assert_eq!(val("occupancy"), 0.5, "1 busy slot of 2");
    assert_eq!(val("decoded_rows"), 0.0, "admission-step requests must not decode-step");
    assert_eq!(val("admission_denied_total"), 0.0);
    assert_eq!(val("kv_blocks_in_use"), 0.0, "release must return the blocks");
    assert!(val("kv_allocs_total") >= 1.0);

    // run facts land as meta, in emission order
    let meta = rec.meta_entries();
    assert_eq!(meta[0].0, "gemm_kernel");
    assert_eq!(meta[1], ("slots", "2".to_string()));
    assert_eq!(meta[2], ("kv_layout", "paged".to_string()));
}

/// A request submitted with `max_new = 0` completes without queueing;
/// its trace is a zero-length `request` span and nothing else.
#[test]
fn zero_max_new_emits_a_degenerate_request_span() {
    let engine = plain_engine(19);
    let rec = RecordingTracer::new();
    let mut s = Scheduler::new(&engine, &opts(2)).unwrap().with_tracer(Box::new(rec.clone()));
    let id = s.submit(RequestSpec::new("1 + 1 =", 0)).unwrap();
    assert!(s.is_idle());
    let events = rec.events();
    assert_eq!(
        sig(&events),
        vec![(Track::Request(id), 'B', "request"), (Track::Request(id), 'E', "request")]
    );
    assert_eq!(events[0].ts_us, events[1].ts_us);
}

/// Span chains stay balanced through every lifecycle edge at once: a
/// paged pool too small for the batch (admission denial + slot reuse),
/// a cancellation while queued, and a cancellation mid-decode. Each
/// request track carries exactly one `request` begin/end pair.
#[test]
fn spans_balance_under_denial_and_cancellation() {
    let engine = plain_engine(8);
    // 2 blocks × 16 tokens: short requests need 1 block each, so at most
    // 2 in flight even though 4 slots exist — every extra request rides
    // the denial/reuse path
    let tight = SchedOptions {
        max_batch: 4,
        kv_budget_bytes: 2 * engine.kv_block_bytes(16),
        kv_paged: true,
        kv_block_size: 16,
        ..SchedOptions::default()
    };
    let rec = RecordingTracer::new();
    let mut s = Scheduler::new(&engine, &tight).unwrap().with_tracer(Box::new(rec.clone()));
    let mut ids = Vec::new();
    for i in 0..5 {
        ids.push(s.submit(RequestSpec::new(format!("{i} + 1 ="), 4)).unwrap());
    }
    // cancel the last while it is still queued: its queued + request
    // spans must close right here
    assert!(s.cancel(ids[4]));
    let report = s.step().unwrap();
    assert!(report.admission_denied >= 1, "pool was meant to deny: {report:?}");
    // best effort mid-decode cancel — whether the victim is still in
    // flight is weight luck, and both outcomes must leave spans balanced
    if let Some(&victim) = report.admitted.first() {
        if s.state_of(victim) == Some(RequestState::Decoding) {
            assert!(s.cancel(victim));
        }
    }
    s.run_until_idle().unwrap();
    assert_eq!(s.take_finished().len(), 5, "a request was lost, not delayed");

    let events = rec.events();
    assert_balanced(&events);
    assert!(events.windows(2).all(|w| w[0].ts_us <= w[1].ts_us));
    for id in ids {
        for (kind, what) in [(EventKind::Begin, "opened"), (EventKind::End, "closed")] {
            let n = events
                .iter()
                .filter(|e| e.track == Track::Request(id) && e.kind == kind && e.name == "request")
                .count();
            assert_eq!(n, 1, "request {id} {what} its lifecycle span {n} times");
        }
    }
    // the denial the report saw is on the counter track too
    let denied = events
        .iter()
        .filter_map(|e| match e.kind {
            EventKind::Counter(v) if e.name == "admission_denied_total" => Some(v),
            _ => None,
        })
        .fold(0.0f64, f64::max);
    assert!(denied >= 1.0);
}

/// Shed observability reconciles end to end: every dropped request gets
/// exactly one zero-length `shed` span on its own track, the span count
/// equals the sum of `SchedStats`' two shed counters, and a metrics
/// registry built from the same stats reports identical totals under the
/// labeled `lota_shed_total` keys — one clock, one count, three views.
#[test]
fn shed_spans_reconcile_with_stats_and_registry() {
    let engine = plain_engine(41);
    let rec = RecordingTracer::new();
    let mut s = Scheduler::new(&engine, &opts(1)).unwrap().with_tracer(Box::new(rec.clone()));
    // a blocker holds the only slot so a queued deadline can expire
    let blocker = s.submit(RequestSpec::new("1 + 2 =", 6)).unwrap();
    s.step().unwrap();
    // blown on arrival: sheds inside the submit call itself
    let at_submit = s.submit(RequestSpec::new("3 + 4 =", 4).deadline_ms(0)).unwrap();
    // blown while waiting: swept at the next step's admission phase
    let in_queue = s.submit(RequestSpec::new("5 + 6 =", 4).deadline_ms(1)).unwrap();
    std::thread::sleep(std::time::Duration::from_millis(5));
    s.run_until_idle().unwrap();
    let stats = s.sched_stats();
    assert_eq!(stats.shed_at_submit, 1);
    assert_eq!(stats.shed_in_queue, 1);
    assert_eq!(s.take_finished().len(), 3);

    let events = rec.events();
    assert_balanced(&events);
    for id in [at_submit, in_queue] {
        let n = events
            .iter()
            .filter(|e| {
                e.track == Track::Request(id) && e.kind == EventKind::Begin && e.name == "shed"
            })
            .count();
        assert_eq!(n, 1, "request {id} should carry exactly one shed span, got {n}");
    }
    let shed_begins =
        events.iter().filter(|e| e.kind == EventKind::Begin && e.name == "shed").count();
    assert_eq!(
        shed_begins,
        stats.shed_at_submit + stats.shed_in_queue,
        "trace shed spans and SchedStats counters diverged"
    );
    assert!(
        !events.iter().any(|e| e.track == Track::Request(blocker) && e.name == "shed"),
        "the surviving request grew a shed span"
    );

    // the registry is the third view of the same counts
    let report = lota_qaf::serve::ThroughputReport::default().with_sched(stats);
    let reg = lota_qaf::obs::MetricsRegistry::from_report(&report);
    assert_eq!(reg.counter("lota_shed_total{reason=\"deadline_at_submit\"}"), Some(1.0));
    assert_eq!(reg.counter("lota_shed_total{reason=\"deadline_in_queue\"}"), Some(1.0));
    assert_eq!(reg.counter("lota_queue_rejected_total"), None, "nothing was queue-rejected");
}

/// Attaching a tracer must not move a single bit of scheduler output:
/// no tracer, `NoopTracer`, and `RecordingTracer` run the same workload
/// to identical generations, decode accounting, and step counts — and
/// an idle step records nothing at all.
#[test]
fn tracing_is_bitwise_inert_on_scheduler_outputs() {
    let run = |tracer: Option<Box<dyn lota_qaf::obs::Tracer>>| {
        let engine = plain_engine(23);
        let mut s = Scheduler::new(&engine, &opts(2)).unwrap();
        if let Some(t) = tracer {
            s = s.with_tracer(t);
        }
        for i in 0..5 {
            s.submit(RequestSpec::new(format!("{i} + 3 ="), [2usize, 6, 4][i % 3])).unwrap();
        }
        s.run_until_idle().unwrap();
        let mut done = s.take_finished();
        done.sort_by_key(|r| r.id);
        let out: Vec<(u64, String, usize)> =
            done.into_iter().map(|r| (r.id, r.text, r.tokens)).collect();
        (out, s.decode_stats(), s.sched_stats().steps)
    };
    let rec = RecordingTracer::new();
    let bare = run(None);
    let noop = run(Some(Box::new(NoopTracer)));
    let recorded = run(Some(Box::new(rec.clone())));
    assert_eq!(bare, noop, "a NoopTracer changed scheduler output");
    assert_eq!(bare, recorded, "a RecordingTracer changed scheduler output");
    assert!(!rec.is_empty(), "the recording run recorded nothing");

    // idle steps emit no events — the no-op path stays a no-op traced
    let idle_rec = RecordingTracer::new();
    let engine = plain_engine(23);
    let mut s = Scheduler::new(&engine, &opts(2)).unwrap().with_tracer(Box::new(idle_rec.clone()));
    s.step().unwrap();
    assert!(idle_rec.is_empty(), "an idle step emitted {} events", idle_rec.len());
}

/// Attaching the engine profiler must not move a single bit either: the
/// profiled GEMM path forces one thread, which is bitwise-pinned against
/// the threaded kernel, and everything else only reads clocks. Same
/// workload, same generations, same decode accounting, same step count.
#[test]
fn profiling_is_bitwise_inert_on_scheduler_outputs() {
    let run = |profiler: Option<Profiler>| {
        let engine = plain_engine(29);
        let mut s = Scheduler::new(&engine, &opts(2)).unwrap();
        if let Some(p) = profiler {
            s = s.with_profiler(p);
        }
        for i in 0..5 {
            s.submit(RequestSpec::new(format!("{i} + 3 ="), [2usize, 6, 4][i % 3])).unwrap();
        }
        s.run_until_idle().unwrap();
        let mut done = s.take_finished();
        done.sort_by_key(|r| r.id);
        let out: Vec<(u64, String, usize)> =
            done.into_iter().map(|r| (r.id, r.text, r.tokens)).collect();
        (out, s.decode_stats(), s.sched_stats().steps)
    };
    let prof = Profiler::new();
    let bare = run(None);
    let profiled = run(Some(prof.clone()));
    assert_eq!(bare, profiled, "attaching a Profiler changed scheduler output");
    assert!(!prof.windows().is_empty(), "the profiled run recorded no windows");
}

/// The tentpole's exactness claim: each window's segment durations tile
/// the window, and `1e3 · total.as_secs_f64()` **bit-equals** the
/// matching `StepReport.prefill_ms` / `decode_ms` — both sides are the
/// same two `Instant`s through the same arithmetic, so `assert_eq!` on
/// f64, no tolerance. Every layer shows its kernel phases.
#[test]
fn engine_phase_sums_reconcile_exactly_with_step_walltimes() {
    let engine = plain_engine(31);
    let prof = Profiler::new();
    let mut s = Scheduler::new(&engine, &opts(2)).unwrap().with_profiler(prof.clone());
    for (i, max_new) in [3usize, 1, 4, 2].into_iter().enumerate() {
        s.submit(RequestSpec::new(format!("{i} + 1 ="), max_new)).unwrap();
    }
    let mut reports = Vec::new();
    while !s.is_idle() {
        reports.push(s.step().unwrap());
    }
    let windows = prof.windows();
    assert!(!windows.is_empty(), "no profiled forwards");
    let n_layers = lota_qaf::config::preset("tiny").unwrap().n_layers as u64;
    let (mut prefills, mut decodes) = (0, 0);
    for w in &windows {
        // step numbers are 1-based; every non-idle step reported in order
        let rep = &reports[w.step as usize - 1];
        let wall_ms = match w.phase {
            ForwardPhase::Prefill => {
                prefills += 1;
                rep.prefill_ms
            }
            ForwardPhase::Decode => {
                decodes += 1;
                rep.decode_ms
            }
        };
        assert_eq!(
            1e3 * w.total.as_secs_f64(),
            wall_ms,
            "window wall-time diverged from the step report: {w:?}"
        );
        let sum: std::time::Duration = w.segments.values().copied().sum();
        assert_eq!(sum, w.total, "segments must tile the window exactly: {w:?}");
        for li in 0..n_layers {
            for kind in [PhaseKind::GemmQkv, PhaseKind::Attention, PhaseKind::GemmO, PhaseKind::GemmMlp] {
                assert!(
                    w.segments.contains_key(&(li, kind)),
                    "layer {li} missing {kind:?} in {:?} window of step {}",
                    w.phase,
                    w.step
                );
            }
        }
        // the step scope always closes the window
        assert!(w.segments.keys().any(|&(tid, _)| tid == STEP_TID));
    }
    assert!(prefills >= 1, "workload never prefilled");
    assert!(decodes >= 1, "workload never decode-stepped");
}

/// With the profiler sinking into the scheduler's own tracer, the Chrome
/// export gains pid-3 engine tracks whose spans sit strictly inside the
/// scheduler's `prefill_forward`/`decode_forward` spans — one clock, so
/// nesting is containment of timestamps, checked on the exported file.
#[test]
fn profiled_chrome_export_nests_engine_tracks_inside_forward_spans() {
    let engine = plain_engine(37);
    let rec = RecordingTracer::new();
    let prof = Profiler::new().with_sink(rec.clone());
    let mut s = Scheduler::new(&engine, &opts(2))
        .unwrap()
        .with_tracer(Box::new(rec.clone()))
        .with_profiler(prof);
    for (i, max_new) in [2usize, 3, 1].into_iter().enumerate() {
        s.submit(RequestSpec::new(format!("{i} + 4 ="), max_new)).unwrap();
    }
    s.run_until_idle().unwrap();

    let doc = Json::parse(&chrome_trace_json(&rec)).unwrap();
    let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
    // collect the scheduler's forward-span intervals (pid 1)
    let mut forwards: Vec<(f64, f64)> = Vec::new();
    let mut open: Option<f64> = None;
    for e in events {
        let ph = e.get("ph").unwrap().as_str().unwrap();
        if ph != "B" && ph != "E" {
            continue;
        }
        let name = e.get("name").unwrap().as_str().unwrap();
        if e.get("pid").unwrap().as_f64().unwrap() == 1.0
            && (name == "prefill_forward" || name == "decode_forward")
        {
            let ts = e.get("ts").unwrap().as_f64().unwrap();
            match ph {
                "B" => open = Some(ts),
                _ => forwards.push((open.take().expect("E without B"), ts)),
            }
        }
    }
    assert!(!forwards.is_empty(), "no forward spans in the trace");

    // every pid-3 engine event must land inside one of those intervals
    let mut engine_spans = 0usize;
    let mut step_scope_seen = false;
    for e in events {
        let ph = e.get("ph").unwrap().as_str().unwrap();
        if ph == "M" || e.get("pid").unwrap().as_f64().unwrap() != 3.0 {
            continue;
        }
        assert_eq!(e.get("cat").unwrap().as_str().unwrap(), "engine");
        let ts = e.get("ts").unwrap().as_f64().unwrap();
        assert!(
            forwards.iter().any(|&(b, t)| b <= ts && ts <= t),
            "engine event at ts {ts} outside every forward span"
        );
        if ph == "B" {
            engine_spans += 1;
            if e.get("tid").unwrap().as_f64().unwrap() == STEP_TID as f64 {
                step_scope_seen = true;
            }
        }
    }
    assert!(engine_spans > 0, "profiler emitted no engine spans");
    assert!(step_scope_seen, "no step-scope engine span in the export");

    // and the pid-3 process is labeled for viewers
    let labels: Vec<String> = events
        .iter()
        .filter(|e| {
            e.get("ph").unwrap().as_str().unwrap() == "M"
                && e.get("pid").unwrap().as_f64().unwrap() == 3.0
        })
        .map(|e| e.get("args").unwrap().get("name").unwrap().as_str().unwrap().to_string())
        .collect();
    assert!(labels.contains(&"engine".to_string()));
    assert!(labels.contains(&"step scope".to_string()));
    assert!(labels.iter().any(|l| l.starts_with("layer ")));
}

/// Span durations and `SchedStats` histograms are the same measurements:
/// emission sites reuse the scheduler's `Instant`s, so the queued span
/// equals the queue-wait sample and request-begin → prefill-end equals
/// the TTFT sample, to float rounding.
#[test]
fn trace_durations_reconcile_with_sched_stats() {
    for seed in 0..16u64 {
        let engine = plain_engine(300 + seed);
        let rec = RecordingTracer::new();
        let mut s = Scheduler::new(&engine, &opts(1)).unwrap().with_tracer(Box::new(rec.clone()));
        let id = s.submit(RequestSpec::new("2 + 2 =", 3)).unwrap();
        s.run_until_idle().unwrap();
        let stats = s.sched_stats();
        if stats.ttft_ms.len() != 1 {
            continue; // first pick was EOS — no first token, next seed
        }
        let events = rec.events();
        let ts = |kind: EventKind, name: &str| {
            events
                .iter()
                .find(|e| e.track == Track::Request(id) && e.kind == kind && e.name == name)
                .unwrap()
                .ts_us
        };
        let queued_ms = (ts(EventKind::End, "queued") - ts(EventKind::Begin, "queued")) / 1e3;
        assert!(
            (queued_ms - stats.queue_wait_ms.stats().mean).abs() < 1e-3,
            "queued span {queued_ms} ms vs queue_wait stat {} ms",
            stats.queue_wait_ms.stats().mean
        );
        let ttft_ms = (ts(EventKind::End, "prefill") - ts(EventKind::Begin, "request")) / 1e3;
        assert!(
            (ttft_ms - stats.ttft_ms.stats().mean).abs() < 1e-3,
            "ttft span {ttft_ms} ms vs ttft stat {} ms",
            stats.ttft_ms.stats().mean
        );
        return;
    }
    panic!("no seed produced a first token in 16 tries");
}

/// The same seeded workload traces to the same event sequence every
/// time (timestamps aside), and the exported Chrome JSON parses back
/// with balanced per-(pid, tid) B/E stacks, labeled tracks, and the run
/// meta — the file-level contract the CI trace-smoke leg checks on the
/// real binary.
#[test]
fn chrome_export_is_deterministic_and_well_formed() {
    let run = || {
        let engine = plain_engine(21);
        let rec = RecordingTracer::new();
        let mut s = Scheduler::new(&engine, &opts(2)).unwrap().with_tracer(Box::new(rec.clone()));
        for (i, max_new) in [1usize, 3, 2].into_iter().enumerate() {
            s.submit(RequestSpec::new(format!("{i} + 2 ="), max_new)).unwrap();
        }
        s.run_until_idle().unwrap();
        rec
    };
    let (a, b) = (run(), run());
    assert_eq!(sig(&a.events()), sig(&b.events()), "same workload, different trace");

    let dir = std::env::temp_dir().join("lota_obs_trace_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("trace.json");
    write_chrome_trace(&path, &a).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    assert_eq!(text, chrome_trace_json(&a), "file and string render diverged");
    std::fs::remove_dir_all(&dir).ok();

    let doc = Json::parse(&text).unwrap();
    assert_eq!(doc.get("displayTimeUnit").unwrap().as_str().unwrap(), "ms");
    let meta = doc.get("meta").unwrap();
    assert!(!meta.get("gemm_kernel").unwrap().as_str().unwrap().is_empty());
    assert_eq!(meta.get("kv_layout").unwrap().as_str().unwrap(), "paged");
    assert_eq!(meta.get("slots").unwrap().as_str().unwrap(), "2");

    let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
    let mut stacks: HashMap<(i64, i64), Vec<String>> = HashMap::new();
    let mut req_threads = 0usize;
    let mut last_ts = 0.0f64;
    for e in events {
        let ph = e.get("ph").unwrap().as_str().unwrap();
        let name = e.get("name").unwrap().as_str().unwrap().to_string();
        if ph == "M" {
            if name == "thread_name" {
                let label = e.get("args").unwrap().get("name").unwrap().as_str().unwrap();
                if label.starts_with("req ") {
                    req_threads += 1;
                }
            }
            continue;
        }
        let ts = e.get("ts").unwrap().as_f64().unwrap();
        assert!(ts >= last_ts, "trace timestamps went backwards");
        last_ts = ts;
        let pid = e.get("pid").unwrap().as_f64().unwrap() as i64;
        let tid = e.get("tid").unwrap().as_f64().unwrap() as i64;
        match ph {
            "B" => stacks.entry((pid, tid)).or_default().push(name),
            "E" => {
                let top = stacks.get_mut(&(pid, tid)).and_then(|s| s.pop());
                assert_eq!(top, Some(name), "unbalanced span on ({pid}, {tid})");
            }
            "C" => {
                e.get("args").unwrap().get("value").unwrap().as_f64().unwrap();
            }
            other => panic!("unexpected phase {other:?}"),
        }
    }
    for (track, stack) in stacks {
        assert!(stack.is_empty(), "track {track:?} left spans open in the file: {stack:?}");
    }
    // one labeled thread per request
    assert_eq!(req_threads, 3);
}
