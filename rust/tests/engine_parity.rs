//! Engine-internal parity: the KV-cached incremental path against the
//! full-recompute reference, pinned **bit-identical** — `assert_eq!` on
//! f32 logits, not a tolerance. Every kernel in the native engine
//! accumulates per row in a fixed order, so feeding fewer rows or fewer
//! positions must not change a single bit of the positions it does feed.
//!
//! Unlike the golden / integration / backend-parity suites, nothing here
//! needs `make artifacts`: the merged checkpoints are synthesized
//! in-process (quantize + fold non-trivial ternary adapters into the
//! grid, the same recipe as `tests/backend_parity.rs`). CI runs this
//! suite on every PR as the native-serving smoke gate.

use lota_qaf::config::{preset, Backend, DecodeMode, ModelConfig, SchedConfig};
use lota_qaf::engine::{greedy_decode, greedy_decode_paged, greedy_decode_with, Engine};
use lota_qaf::model;
use lota_qaf::quant::rtn_quantize;
use lota_qaf::sched::{RequestSpec, SchedOptions, Scheduler};
use lota_qaf::serve::{serve_batch, ServeOptions, ServePath};
use lota_qaf::tensor::{Rng, Tensor};

mod common;
use common::merged_tiny;

fn merged_engine(seed: u64) -> (ModelConfig, Engine) {
    let (cfg, store) = merged_tiny(seed);
    let engine = Engine::from_store(&cfg, &store, 4).unwrap();
    (cfg, engine)
}

/// A plain RTN-quantized tiny engine (no ternary merge) — cheaper to
/// build, used where the test only needs *some* fixed weights per seed.
fn plain_engine(seed: u64) -> Engine {
    let cfg = preset("tiny").unwrap();
    let mut rng = Rng::new(seed);
    let fp = model::init_fp(&cfg, &mut rng);
    let store = model::quantize_store(&cfg, &fp, |_, _, w| {
        Ok(rtn_quantize(w, cfg.group_size, 4))
    })
    .unwrap();
    Engine::from_store(&cfg, &store, 4).unwrap()
}

/// Property: over random token streams, chunked incremental forwards
/// (arbitrary prefill chunk boundaries, batch sizes, prefix lengths)
/// reproduce the full forward's logits bit-for-bit at every position.
#[test]
fn incremental_chunking_matches_full_forward_bitwise() {
    let (cfg, engine) = merged_engine(101);
    let v = cfg.vocab;
    let mut rng = Rng::new(202);
    for case in 0..12u64 {
        let b = 1 + rng.below(4); // 1..=4 rows
        let t = 4 + rng.below(37); // 4..=40 positions
        let tokens = Tensor::new(
            &[b, t],
            (0..b * t).map(|_| rng.below(cfg.vocab) as f32).collect(),
        );
        let full = engine.forward(&tokens).unwrap();

        // random chunking of the prefix: always exercises chunk sizes 1
        // and >1, and the final chunk ends exactly at t
        let mut cache = engine.new_cache(b);
        let rows: Vec<usize> = (0..b).collect();
        let mut t0 = 0usize;
        while t0 < t {
            let chunk = match rng.below(3) {
                0 => 1,
                1 => 2 + rng.below(5),
                _ => t - t0, // the rest in one go
            }
            .min(t - t0);
            let mut step = vec![0.0f32; b * chunk];
            for bi in 0..b {
                step[bi * chunk..(bi + 1) * chunk]
                    .copy_from_slice(&tokens.data()[bi * t + t0..bi * t + t0 + chunk]);
            }
            let got = engine
                .forward_incremental(&Tensor::new(&[b, chunk], step), &mut cache, &rows)
                .unwrap();
            assert_eq!(got.shape(), &[b, chunk, v]);
            for bi in 0..b {
                for ti in 0..chunk {
                    assert_eq!(
                        &got.data()[(bi * chunk + ti) * v..(bi * chunk + ti + 1) * v],
                        &full.data()[(bi * t + t0 + ti) * v..(bi * t + t0 + ti + 1) * v],
                        "case {case}: logits diverge at row {bi} position {}",
                        t0 + ti
                    );
                }
            }
            t0 += chunk;
        }
        for bi in 0..b {
            assert_eq!(cache.pos_len(bi), t);
        }
    }
}

/// Cached and recompute greedy decoding produce identical generations —
/// texts and step counts — across batch sizes, on a non-trivially merged
/// checkpoint. The default `greedy_decode` is the cached path.
#[test]
fn cached_and_recompute_decodes_are_identical() {
    let (cfg, engine) = merged_engine(103);
    assert_eq!(cfg.name, "tiny");
    for b in [1usize, 4, 9] {
        let prompts: Vec<String> = (0..b).map(|i| format!("{i} + {} =", (i * 7) % 10)).collect();
        let (cached, cs) =
            greedy_decode_with(&engine, &prompts, 8, DecodeMode::Cached).unwrap();
        let (recomp, rs) =
            greedy_decode_with(&engine, &prompts, 8, DecodeMode::Recompute).unwrap();
        let default = greedy_decode(&engine, &prompts, 8).unwrap();
        assert_eq!(cached.len(), b);
        for i in 0..b {
            assert_eq!(cached[i].text, recomp[i].text, "b={b} prompt {i}");
            assert_eq!(cached[i].tokens, recomp[i].tokens, "b={b} prompt {i}");
            assert_eq!(cached[i].text, default[i].text, "default decode is not cached");
        }
        assert_eq!(cs.forwards, rs.forwards, "b={b}: step counts diverge");
        assert!(
            cs.forwarded_positions <= rs.forwarded_positions,
            "b={b}: cached fed more than recompute"
        );
    }
}

/// Regression for the full-batch-until-everyone-finishes bug: on prompts
/// whose generations finish at different steps, later step batches must
/// shrink — `forwarded_rows` strictly below `batch × forwards`. Whether a
/// given random model EOSes early at all is weight luck (empirically a
/// few percent of seeds), so scan seeds with the cheap cached decode for
/// one that staggers, then pin the recompute path's accounting on it. If
/// the whole scan comes up empty (overwhelmingly unlikely, but not a
/// code bug), fall back to asserting the non-staggered invariant instead
/// of flaking.
#[test]
fn finished_rows_leave_the_step_batch() {
    let b = 6usize;
    let max_new = 16usize;
    // the first staggering (seed, prompts) pair can't be pre-pinned
    // without a toolchain to discover it, but the scan is fully
    // deterministic, so it stops at the same point on every run
    // (empirically a few percent of random models stagger; two prompt
    // sets per engine double the trials at little extra cost)
    let mut staggered = None;
    'scan: for seed in 0..96u64 {
        // plain engines keep the repeated scan prefix cheap
        let engine = plain_engine(1000 + seed);
        for variant in 0..2usize {
            let prompts: Vec<String> = (0..b)
                .map(|i| format!("{} + {i} =", (seed as usize + 3 * i + 5 * variant) % 10))
                .collect();
            let (gens, stats) =
                greedy_decode_with(&engine, &prompts, max_new, DecodeMode::Cached).unwrap();
            let counts: Vec<usize> = gens.iter().map(|g| g.tokens).collect();
            if stats.forwarded_rows < b * stats.forwards {
                // a later step batch shrank — rows must have finished at
                // different times
                assert!(
                    counts.iter().min() < counts.iter().max(),
                    "seed {seed}: shrunken step batch without staggered finishes: {counts:?} {stats:?}"
                );
                staggered = Some((engine, prompts, gens, stats));
                break 'scan;
            }
            // no shrink ⇒ every forward carried the full batch
            assert_eq!(stats.forwarded_rows, b * stats.forwards, "seed {seed}: {stats:?}");
        }
    }
    let Some((engine, prompts, gens, cstats)) = staggered else {
        // only a few percent of random tiny models EOS early; missing the
        // whole scan is vanishingly unlikely but not a code bug — note it
        // rather than flake; the shrink mechanism itself is pinned at the
        // forward level by incremental_skips_finished_rows_independently
        eprintln!("finished_rows_leave_the_step_batch: no staggered seed in scan, skipping");
        return;
    };
    // the recompute reference shrinks its step batches identically and
    // agrees token-for-token while feeding far more positions
    let (recomp, rstats) =
        greedy_decode_with(&engine, &prompts, max_new, DecodeMode::Recompute).unwrap();
    for (c, r) in gens.iter().zip(&recomp) {
        assert_eq!(c.text, r.text);
        assert_eq!(c.tokens, r.tokens);
    }
    assert!(rstats.forwarded_rows < b * rstats.forwards, "recompute kept finished rows");
    assert_eq!(cstats.forwarded_rows, rstats.forwarded_rows, "same rows, different strategy");
    assert!(cstats.forwarded_positions < rstats.forwarded_positions);
}

/// Scheduled greedy decoding is pinned **bit-identical** to the one-shot
/// cached decode (PR 2's `greedy_decode`) on the same prompts — for a
/// batch that fits in one admission wave, for waves forced by a small
/// slot pool, and for serial slot reuse (one slot, every request recycles
/// the same cache row) — under **both** KV layouts, paged and contiguous.
/// The scheduler drives the same prefill/step kernels and cache rows
/// never interact, so text *and* token counts must match exactly.
#[test]
fn scheduled_decode_is_bit_identical_to_one_shot() {
    let (cfg, engine) = merged_engine(401);
    assert_eq!(cfg.name, "tiny");
    let prompts: Vec<String> = (0..9).map(|i| format!("{i} + {} =", (i * 3) % 10)).collect();
    let max_new = 8usize;
    let want = greedy_decode(&engine, &prompts, max_new).unwrap();
    // slot pools: everyone at once / three admission waves / serial reuse
    for max_batch in [9usize, 3, 1] {
        for kv_paged in [true, false] {
            let sched_opts = SchedOptions { max_batch, kv_paged, ..SchedOptions::default() };
            let mut sched = Scheduler::new(&engine, &sched_opts).unwrap();
            let ids: Vec<u64> =
                prompts
                    .iter()
                    .map(|p| sched.submit(RequestSpec::new(p.as_str(), max_new)).unwrap())
                    .collect();
            sched.run_until_idle().unwrap();
            let responses = sched.take_finished();
            assert_eq!(responses.len(), prompts.len());
            for (i, id) in ids.iter().enumerate() {
                let got = responses.iter().find(|r| r.id == *id).unwrap();
                assert_eq!(
                    got.text, want[i].text,
                    "max_batch {max_batch} paged {kv_paged}: prompt {i} diverged from one-shot"
                );
                assert_eq!(
                    got.tokens, want[i].tokens,
                    "max_batch {max_batch} paged {kv_paged}: prompt {i}"
                );
            }
        }
    }
}

/// Property: a paged cache reproduces the full forward's logits
/// bit-for-bit through random prefill chunkings — the paged counterpart
/// of `incremental_chunking_matches_full_forward_bitwise`, with block
/// sizes that divide the positions evenly and ones that never do.
#[test]
fn paged_chunking_matches_full_forward_bitwise() {
    let (cfg, engine) = merged_engine(101);
    let v = cfg.vocab;
    let mut rng = Rng::new(505);
    for (case, &bs) in [1usize, 3, 16].iter().enumerate() {
        let b = 1 + rng.below(3); // 1..=3 rows
        let t = 6 + rng.below(30); // 6..=35 positions
        let tokens = Tensor::new(
            &[b, t],
            (0..b * t).map(|_| rng.below(cfg.vocab) as f32).collect(),
        );
        let full = engine.forward(&tokens).unwrap();
        let pool = b * cfg.seq_len.div_ceil(bs);
        let mut cache = engine.new_cache_paged(b, cfg.seq_len, bs, pool).unwrap();
        let rows: Vec<usize> = (0..b).collect();
        let mut t0 = 0usize;
        while t0 < t {
            let chunk = match rng.below(3) {
                0 => 1,
                1 => 2 + rng.below(5),
                _ => t - t0,
            }
            .min(t - t0);
            let mut step = vec![0.0f32; b * chunk];
            for bi in 0..b {
                step[bi * chunk..(bi + 1) * chunk]
                    .copy_from_slice(&tokens.data()[bi * t + t0..bi * t + t0 + chunk]);
            }
            let got = engine
                .forward_incremental(&Tensor::new(&[b, chunk], step), &mut cache, &rows)
                .unwrap();
            for bi in 0..b {
                for ti in 0..chunk {
                    assert_eq!(
                        &got.data()[(bi * chunk + ti) * v..(bi * chunk + ti + 1) * v],
                        &full.data()[(bi * t + t0 + ti) * v..(bi * t + t0 + ti + 1) * v],
                        "case {case} bs {bs}: paged logits diverge at row {bi} position {}",
                        t0 + ti
                    );
                }
            }
            t0 += chunk;
        }
        for bi in 0..b {
            assert_eq!(cache.pos_len(bi), t);
            assert_eq!(cache.row_block_ids(bi).len(), t.div_ceil(bs));
        }
    }
}

/// One-shot paged greedy decoding matches the contiguous default exactly
/// — generations *and* decode-work accounting — on a non-trivially merged
/// checkpoint.
#[test]
fn paged_one_shot_decode_is_bit_identical() {
    let (_cfg, engine) = merged_engine(407);
    for b in [1usize, 4, 9] {
        let prompts: Vec<String> =
            (0..b).map(|i| format!("{i} - {} =", (i * 5) % 10)).collect();
        let (want, ws) = greedy_decode_with(&engine, &prompts, 8, DecodeMode::Cached).unwrap();
        for bs in [1usize, 7, 16] {
            let (got, gs) = greedy_decode_paged(&engine, &prompts, 8, bs).unwrap();
            for i in 0..b {
                assert_eq!(got[i].text, want[i].text, "b={b} bs={bs} prompt {i}");
                assert_eq!(got[i].tokens, want[i].tokens, "b={b} bs={bs} prompt {i}");
            }
            assert_eq!(gs, ws, "b={b} bs={bs}: work accounting diverged");
        }
    }
}

/// The scheduled serving path end to end (ServeOptions → ScheduledBackend
/// → Server drain): same generated tokens as the one-shot native path,
/// same decode-work accounting when the batch fits one wave, scheduler
/// measurements in the report.
#[test]
fn scheduled_serving_smoke_without_artifacts() {
    let (cfg, store) = merged_tiny(403);
    let prompts: Vec<String> = (0..6).map(|i| format!("{i} - 2 =")).collect();
    let one_shot = ServeOptions::new(ServePath::Merged, 5).backend(Backend::Native);
    let scheduled = ServeOptions::new(ServePath::Merged, 5)
        .backend(Backend::Native)
        .scheduled(SchedConfig::default());
    let rep_o = serve_batch(None, &cfg, &store, &one_shot, &prompts).unwrap();
    let rep_s = serve_batch(None, &cfg, &store, &scheduled, &prompts).unwrap();
    assert_eq!(rep_o.tokens, rep_s.tokens, "scheduling changed the generations");
    // 6 requests fit the default 8-slot pool: identical work accounting
    assert_eq!(rep_o.decode, rep_s.decode);
    let sched = rep_s.sched.as_ref().expect("scheduled report lost its measurements");
    assert_eq!(sched.queue_wait_ms.len(), 6);
    assert!(sched.steps > 0);
    assert!(rep_o.sched.is_none());
}

/// The no-artifact serving smoke CI runs on every PR: a synthetic merged
/// checkpoint served through `NativeBackend` in both decode modes, end to
/// end through the batcher and metrics, with zero files on disk.
#[test]
fn native_serving_smoke_without_artifacts() {
    let (cfg, store) = merged_tiny(105);
    let prompts: Vec<String> = (0..7).map(|i| format!("{i} + 2 =")).collect();
    let mut reports = Vec::new();
    for mode in [DecodeMode::Cached, DecodeMode::Recompute] {
        let opts = ServeOptions::new(ServePath::Merged, 6)
            .backend(Backend::Native)
            .decode_mode(mode);
        let report = serve_batch(None, &cfg, &store, &opts, &prompts).unwrap();
        assert_eq!(report.requests, 7, "{mode:?}");
        assert!(report.tokens <= 7 * 6);
        assert!(report.wall_secs > 0.0);
        assert!(report.decode.forwards > 0, "{mode:?} reported no decode work");
        reports.push(report);
    }
    // both modes served the same generations and say so in the accounting
    assert_eq!(reports[0].tokens, reports[1].tokens);
    assert!(reports[0].decode.forwarded_positions <= reports[1].decode.forwarded_positions);
}

/// The LoRA serving path (quantized base + f32 adapter matmuls) also
/// decodes identically under both strategies — the cache stores post-GEMM
/// K/V rows, adapter contribution included.
#[test]
fn lora_path_decodes_identically_in_both_modes() {
    let cfg = preset("tiny").unwrap();
    let mut rng = Rng::new(301);
    let fp = model::init_fp(&cfg, &mut rng);
    let mut store =
        model::quantize_store(&cfg, &fp, |_, _, w| Ok(rtn_quantize(w, cfg.group_size, 4)))
            .unwrap();
    model::init_adapters(&cfg, lota_qaf::config::Method::Lora, &mut rng, &mut store);
    for (slot, _, _) in cfg.slots() {
        let t = store.get_mut(&format!("lo_{slot}_b")).unwrap();
        for v in t.data_mut() {
            *v = 0.01;
        }
    }
    let prompts: Vec<String> = (0..3).map(|i| format!("{i} - 1 =")).collect();
    let mut texts = Vec::new();
    for mode in [DecodeMode::Cached, DecodeMode::Recompute] {
        let opts = ServeOptions::new(ServePath::LoraAdapter, 5)
            .backend(Backend::Native)
            .decode_mode(mode);
        let report = serve_batch(None, &cfg, &store, &opts, &prompts).unwrap();
        texts.push(report.tokens);
    }
    assert_eq!(texts[0], texts[1], "lora path decodes diverge between modes");
}
